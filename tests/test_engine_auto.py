"""``engine="auto"``: batched-when-eligible, array otherwise — recorded.

The auto engine's contract (:func:`repro.flashsim.engine_batched.
resolve_engine`) has three clauses, each pinned here:

  * **never changes results** — an auto run equals both the explicit
    array run and (when eligible) the explicit batched run, full
    SimStats equality, across the run APIs;
  * **records its decision** — ``SimStats.engine_selected`` carries the
    concrete engine that ran, and ``engine_fallback_reason`` carries
    the exact :class:`BatchedUnsupported` message the explicit batched
    engine would have raised (empty when batched ran) — auto documents,
    never hides, its fallback;
  * **observability fields stay out of equality** — selection metadata
    is ``compare=False``, so auto-vs-explicit equality compares the
    simulation outcome, not the selection path.
"""

import dataclasses

import pytest

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    OperatingCondition,
)
from repro.flashsim.engine_batched import resolve_engine
from repro.flashsim.ssd import (
    SimStats,
    compare_mechanisms,
    simulate,
    simulate_batch,
)

AGED = OperatingCondition(365.0, 1000.0)


def _trio(n=400, **kw):
    a = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=n,
                 engine="array", **kw)
    b = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=n,
                 engine="batched", **kw)
    c = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=n,
                 engine="auto", **kw)
    return a, b, c


class TestAutoSelection:
    @pytest.mark.parametrize("scheduler,gc", [
        ("fcfs", None), ("host_prio", "prepass"),
        ("host_prio_aged:3", "prepass"),
    ])
    def test_eligible_cells_pick_batched(self, scheduler, gc):
        a, b, c = _trio(scheduler=scheduler, gc=gc)
        assert a == b == c
        assert c.engine_selected == "batched"
        assert c.engine_fallback_reason == ""
        assert c.fast_path_events > 0

    def test_explicit_engines_record_themselves(self):
        a, b, _ = _trio()
        assert a.engine_selected == "array"
        assert b.engine_selected == "batched"
        assert a.engine_fallback_reason == b.engine_fallback_reason == ""

    def test_selection_metadata_excluded_from_equality(self):
        fields = {f.name: f for f in dataclasses.fields(SimStats)}
        assert not fields["engine_selected"].compare
        assert not fields["engine_fallback_reason"].compare


class TestAutoFallback:
    """Every explicit-rejection axis falls back — with the reason."""

    @pytest.mark.parametrize("kw,needle", [
        (dict(scheduler="tokens"), "ring-lowerable"),
        (dict(scheduler="preempt"), "ring-lowerable"),
        (dict(gc="online"), "online GC"),
        (dict(faults=FaultConfig()), "fault"),
        (dict(ncq_depth=8), "open-loop"),
        (dict(validate=True), "validate"),
    ])
    def test_fallback_records_reason(self, kw, needle):
        c = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=200,
                     engine="auto", **kw)
        assert c.engine_selected == "array"
        assert needle in c.engine_fallback_reason
        assert c.fast_path_events == 0

    def test_fallback_equals_explicit_array(self):
        a = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=300,
                     engine="array", scheduler="tokens")
        c = simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=300,
                     engine="auto", scheduler="tokens")
        assert a == c

    def test_resolve_engine_reason_is_the_raised_message(self):
        from repro.flashsim.engine_batched import (
            BatchedUnsupported,
            check_batched_config,
        )

        cfg = dataclasses.replace(DEFAULT_SSD, scheduler="tokens")
        eng, reason = resolve_engine(cfg)
        assert eng == "array"
        with pytest.raises(BatchedUnsupported) as ei:
            check_batched_config(cfg)
        assert reason == str(ei.value)


class TestAutoAcrossRunAPIs:
    def test_cfg_engine_auto(self):
        cfg = dataclasses.replace(DEFAULT_SSD, engine="auto")
        c = simulate("websearch", AGED, "baseline", n_requests=300,
                     cfg=cfg)
        a = simulate("websearch", AGED, "baseline", n_requests=300)
        assert a == c
        assert c.engine_selected == "batched"

    def test_compare_mechanisms_auto(self):
        a = compare_mechanisms("websearch", AGED, seed=1, n_requests=400,
                               engine="array", scheduler="host_prio")
        c = compare_mechanisms("websearch", AGED, seed=1, n_requests=400,
                               engine="auto", scheduler="host_prio")
        assert list(a) == list(c)
        assert all(a[m] == c[m] for m in a)
        assert all(s.engine_selected == "batched" for s in c.values())

    def test_simulate_batch_auto(self):
        conds = (AGED, OperatingCondition(30.0, 0.0))
        a = simulate_batch("websearch", conds,
                           mechanisms=("baseline", "pr2ar2"),
                           seeds=(0, 1), n_requests=300, engine="array")
        c = simulate_batch("websearch", conds,
                           mechanisms=("baseline", "pr2ar2"),
                           seeds=(0, 1), n_requests=300, engine="auto")
        assert list(a) == list(c)
        assert all(a[k] == c[k] for k in a)
