"""Checkpoint: parity reconstruction, pipelined restore, manager fallback."""

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    corrupt_shard,
    delete_shard,
    restore,
    save,
)


@pytest.fixture
def tree():
    rng = np.random.default_rng(0)
    return {
        "a": rng.normal(size=(100, 1000)).astype(np.float32),
        "b": {"w": np.ones((333, 77), np.float32), "s": np.int32(7)},
        "c": [rng.normal(size=(512, 256)).astype(np.float32) for _ in range(5)],
    }


def _assert_tree_equal(x, y):
    import jax

    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSaveRestore:
    @pytest.mark.parametrize("pipelined", [True, False])
    def test_roundtrip(self, tmp_path, tree, pipelined):
        save(tmp_path / "ck", tree, shard_bytes=1 << 19, parity_group=3)
        out, st = restore(tmp_path / "ck", tree, pipelined=pipelined)
        _assert_tree_equal(out, tree)
        assert st.n_reconstructed == 0 and st.n_failed == 0
        assert st.pipelined == pipelined

    def test_single_corruption_per_group_recovers(self, tmp_path, tree):
        d = save(tmp_path / "ck", tree, shard_bytes=1 << 19, parity_group=3)
        corrupt_shard(d, 1)
        out, st = restore(d, tree)
        _assert_tree_equal(out, tree)
        assert st.n_reconstructed == 1

    def test_lost_shard_recovers(self, tmp_path, tree):
        d = save(tmp_path / "ck", tree, shard_bytes=1 << 19, parity_group=3)
        delete_shard(d, 4)
        out, st = restore(d, tree)
        _assert_tree_equal(out, tree)
        assert st.n_reconstructed == 1

    def test_two_failures_one_group_raises(self, tmp_path, tree):
        d = save(tmp_path / "ck", tree, shard_bytes=1 << 19, parity_group=3)
        corrupt_shard(d, 0)
        corrupt_shard(d, 1)  # same parity group of 3
        with pytest.raises(IOError):
            restore(d, tree)

    def test_failures_in_different_groups_recover(self, tmp_path, tree):
        d = save(tmp_path / "ck", tree, shard_bytes=1 << 19, parity_group=2)
        corrupt_shard(d, 0)
        delete_shard(d, 3)  # group 1 (shards 2,3)
        out, st = restore(d, tree)
        _assert_tree_equal(out, tree)
        assert st.n_reconstructed == 2


class TestManager:
    def test_rotation_and_fallback(self, tmp_path, tree):
        mgr = CheckpointManager(tmp_path, keep=2, save_every=10,
                                parity_group=3, shard_bytes=1 << 19)
        for s in (10, 20, 30):
            mgr.save(s, tree)
        assert mgr.steps() == [20, 30]
        # newest beyond margin -> fall back to 20
        corrupt_shard(mgr._dir(30), 0)
        corrupt_shard(mgr._dir(30), 1)
        step, out, st = mgr.restore_latest(tree)
        assert step == 20
        _assert_tree_equal(out, tree)

    def test_uncommitted_checkpoint_invisible(self, tmp_path, tree):
        mgr = CheckpointManager(tmp_path, keep=3, save_every=10)
        mgr.save(10, tree)
        d = mgr.save(20, tree)
        (d / "COMMITTED").unlink()  # simulate crash mid-save
        assert mgr.steps() == [10]
        step, _, _ = mgr.restore_latest(tree)
        assert step == 10

    def test_should_save(self, tmp_path):
        mgr = CheckpointManager(tmp_path, save_every=50)
        assert mgr.should_save(50) and mgr.should_save(100)
        assert not mgr.should_save(0) and not mgr.should_save(51)
