"""Closed-loop host frontend (ISSUE 7): NCQ admission, write-back
cache, explicit channel DMA phase — and the contract that ties it down.

Two halves:

* **Bit-parity** — with ``ncq_depth=None`` (the default) the simulator
  must be bit-identical to the build before the closed-loop code landed.
  ``tests/data/golden_closed_loop.json`` pins the full scheduler x GC x
  faults matrix (plus two extra mechanism cells) at that build; every
  pinned field is compared exactly, across ``shard=`` and ``workers=``.
* **Closed-loop semantics** — NCQ slot discipline, queue-wait/device
  decomposition, saturation ladder shape (monotone throughput with a
  knee), QD-bounded device-side read p99 on GC write-cliff profiles,
  write-back cache absorption/hit/backpressure, fault-set invariance,
  and the journal schema-drift tolerance (satellite fix).
"""

import dataclasses
import json
import pathlib

import numpy as np
import pytest

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    HostCacheConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.hostcache import WriteCache
from repro.flashsim.runtime import (
    Cell,
    run_cells,
    run_sweep,
    sweep_to_json,
    _stats_from_journal,
)
from repro.flashsim.ssd import (
    SimStats,
    compare_mechanisms,
    simulate,
    simulate_batch,
)

DATA = pathlib.Path(__file__).resolve().parent / "data"
GOLDEN = json.loads((DATA / "golden_closed_loop.json").read_text())
AGED = OperatingCondition(365.0, 1000.0)
N = GOLDEN["meta"]["n_requests"]

#: Fields the closed-loop PR added (all zero-defaulted): absent from the
#: golden file by construction, asserted zero on open-loop runs.
CLOSED_FIELDS = (
    "hostq_wait_mean_us", "hostq_wait_p99_us", "device_mean_us",
    "read_device_p99_us", "throughput_iops", "max_inflight",
    "cache_hit_reads", "cache_hit_pages", "cache_absorbed_writes",
    "cache_flush_pages", "cache_stalled_writes", "die_sense_util",
)

FAULT_FIELDS = (
    "mispredicted_reads", "rescued_reads", "parity_rebuilds",
    "rebuild_reads", "retired_blocks", "program_fails", "erase_fails",
    "unrecoverable",
)


def _golden_faults(name):
    if name == "none":
        return None
    d = GOLDEN["meta"]["fault_configs"][name]
    return FaultConfig(**d)


def _cell_args(key):
    mech, sched, gc, fname = key.split("|")
    wl = GOLDEN["meta"]["extra_workload"] if mech in (
        "baseline", "sota+pr2ar2") else GOLDEN["meta"]["workload"]
    return wl, mech, sched, gc, _golden_faults(fname)


def _assert_pinned(stats, want, ctx):
    got = dataclasses.asdict(stats)
    for field, v in want.items():
        assert got[field] == v, (
            f"{ctx}.{field}: open-loop output drifted from the "
            f"pre-closed-loop build ({got[field]!r} != {v!r})"
        )


class TestOpenLoopBitParity:
    """``ncq_depth=None`` is byte-for-byte the PR-6 simulator."""

    @pytest.mark.parametrize("key", sorted(GOLDEN["cells"]))
    def test_matrix_cell(self, key):
        wl, mech, sched, gc, faults = _cell_args(key)
        stats = simulate(
            wl, AGED, mech, seed=GOLDEN["meta"]["seed"], n_requests=N,
            scheduler=sched, gc=gc, faults=faults,
        )
        _assert_pinned(stats, GOLDEN["cells"][key], key)

    @pytest.mark.parametrize("key", [
        "pr2ar2|fcfs|prepass|fc",
        "pr2ar2|host_prio|online|none",
        "pr2ar2|tokens:4,2|off|fc",
    ])
    def test_matrix_cell_sharded(self, key):
        """shard=True stays on the same pinned numbers."""
        wl, mech, sched, gc, faults = _cell_args(key)
        stats = simulate(
            wl, AGED, mech, seed=GOLDEN["meta"]["seed"], n_requests=N,
            scheduler=sched, gc=gc, faults=faults, shard=True,
        )
        _assert_pinned(stats, GOLDEN["cells"][key], f"{key}[shard]")

    @pytest.mark.parametrize("workers", [1, 2])
    def test_matrix_cell_through_workers(self, workers):
        """The sweep runtime (either worker count) hits the same pin."""
        key = "pr2ar2|host_prio_aged:8|prepass|fc"
        wl, mech, sched, gc, faults = _cell_args(key)
        cells = [Cell("simulate", wl, (AGED,), (mech,),
                      GOLDEN["meta"]["seed"], n_requests=N,
                      scheduler=sched, gc=gc, faults=faults)]
        [stats] = run_cells(cells, workers=workers)
        _assert_pinned(stats, GOLDEN["cells"][key], f"{key}[w{workers}]")

    def test_new_fields_zero_on_open_loop(self):
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=200,
                         gc="prepass")
        for f in CLOSED_FIELDS:
            assert getattr(stats, f) == 0, f"{f} must default to 0 open-loop"


class TestConfigValidation:
    def test_ncq_depth_must_be_positive(self):
        with pytest.raises(ValueError, match="ncq_depth"):
            dataclasses.replace(DEFAULT_SSD, ncq_depth=0)

    def test_host_cache_requires_ncq(self):
        with pytest.raises(ValueError, match="host_cache"):
            dataclasses.replace(DEFAULT_SSD,
                                host_cache=HostCacheConfig())

    def test_watermark_ordering(self):
        with pytest.raises(ValueError):
            HostCacheConfig(flush_high=0.3, flush_low=0.6)
        with pytest.raises(ValueError):
            HostCacheConfig(capacity_pages=0)

    def test_unsupported_combinations_raise(self):
        with pytest.raises(NotImplementedError, match="online"):
            simulate("prn", AGED, "pr2ar2", seed=0, n_requests=100,
                     gc="online", ncq_depth=8)
        with pytest.raises(NotImplementedError, match="preempt"):
            simulate("prn", AGED, "pr2ar2", seed=0, n_requests=100,
                     scheduler="preempt", gc="prepass", ncq_depth=8)
        with pytest.raises(NotImplementedError, match="array engine"):
            simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=50,
                     engine="reference", ncq_depth=8)


class TestNCQAdmission:
    def test_inflight_never_exceeds_depth(self):
        for qd in (1, 3, 8):
            stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=300,
                             gc="prepass", ncq_depth=qd, validate=True)
            assert 1 <= stats.max_inflight <= qd

    def test_depth_one_serializes(self):
        stats = simulate("websearch", AGED, "pr2ar2", seed=0,
                         n_requests=300, ncq_depth=1)
        assert stats.max_inflight == 1
        # Fully serialized: queue wait dominates, throughput is the
        # reciprocal of the mean device time (one request at a time).
        assert stats.hostq_wait_mean_us > 0.0
        per_req = 1e6 / stats.throughput_iops
        assert per_req >= stats.device_mean_us

    def test_wait_plus_device_decomposition(self):
        """response = hostq wait + device time + host overhead, exactly
        (means; the engine computes all three from the same arrays)."""
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", ncq_depth=4)
        lhs = stats.hostq_wait_mean_us + stats.device_mean_us \
            + DEFAULT_SSD.host_overhead_us
        assert lhs == pytest.approx(stats.mean_us, rel=1e-9)

    def test_deep_queue_converges_to_open_loop(self):
        """A queue deeper than the trace ever needs admits everything at
        its arrival time — identical latencies to the open loop."""
        open_ = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass")
        closed = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                          gc="prepass", ncq_depth=10_000)
        assert closed.mean_us == pytest.approx(open_.mean_us, rel=1e-12)
        assert closed.read_p99_us == pytest.approx(open_.read_p99_us,
                                                   rel=1e-12)
        assert closed.hostq_wait_mean_us == 0.0

    def test_closed_loop_deterministic(self):
        a = simulate("prn", AGED, "pr2ar2", seed=3, n_requests=300,
                     gc="prepass", ncq_depth=8,
                     host_cache=HostCacheConfig(capacity_pages=64))
        b = simulate("prn", AGED, "pr2ar2", seed=3, n_requests=300,
                     gc="prepass", ncq_depth=8,
                     host_cache=HostCacheConfig(capacity_pages=64))
        assert a == b

    def test_shard_flag_ignored_under_closed_loop(self):
        """The NCQ couples channels through the shared slot pool, so
        ``shard=`` must not change closed-loop results."""
        a = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=300,
                     gc="prepass", ncq_depth=8, shard=False)
        b = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=300,
                     gc="prepass", ncq_depth=8, shard=True)
        assert a == b


class TestSaturation:
    LADDER = (1, 2, 4, 8, 16, 32)

    def _ladder(self, wl, mech="pr2ar2", n=600, **kw):
        return [
            simulate(wl, AGED, mech, seed=0, n_requests=n, gc="prepass",
                     ncq_depth=qd, **kw)
            for qd in self.LADDER
        ]

    def test_throughput_monotone_with_knee(self):
        iops = [s.throughput_iops for s in self._ladder("prn")]
        for lo, hi in zip(iops, iops[1:]):
            assert hi >= lo * (1 - 1e-9), f"throughput dropped: {iops}"
        # Near-linear scaling at the bottom of the ladder...
        assert iops[1] / iops[0] > 1.7
        # ...and a knee: the top rung no longer doubles.
        assert iops[-1] / iops[-2] < 1.5

    @pytest.mark.parametrize("wl", ["prn", "src"])
    def test_read_p99_qd_bounded_on_gc_cliff(self, wl):
        """Admission control bounds the *device-side* read p99: on GC
        write-cliff profiles it never exceeds what the open loop (which
        dumps the whole trace into the device queues) reaches."""
        open_p99 = simulate(wl, AGED, "pr2ar2", seed=0, n_requests=600,
                            gc="prepass").read_p99_us
        for qd, s in zip(self.LADDER, self._ladder(wl)):
            if qd > 16:
                continue       # top rungs converge to the open loop
            assert s.read_device_p99_us <= open_p99 * (1 + 1e-9), qd

    def test_pr2_overlap_win_closed_loop(self):
        """CACHE READ pipelining overlaps the next sense with the current
        channel transfer — at a fixed QD the pipelined mechanism must
        beat the serial baseline on throughput AND read p99."""
        base = simulate("websearch", AGED, "baseline", seed=0,
                        n_requests=600, ncq_depth=8)
        pipe = simulate("websearch", AGED, "sota+pr2ar2", seed=0,
                        n_requests=600, ncq_depth=8)
        assert pipe.throughput_iops > base.throughput_iops * 1.2
        assert pipe.read_p99_us < base.read_p99_us
        assert pipe.die_sense_util > 0.0


class TestWriteCacheUnit:
    def test_absorb_hit_and_versions(self):
        c = WriteCache(HostCacheConfig(capacity_pages=8))
        c.absorb([10, 11])
        assert c.contains(10) and c.contains(11) and not c.contains(12)
        v1 = c.version(10)
        c.absorb([10])                       # rewrite: new version, new slot
        assert c.version(10) > v1
        assert c.pending_pages == 3 and c.dirty_pages == 3

    def test_fifo_flush_and_durable_raw_order(self):
        c = WriteCache(HostCacheConfig(capacity_pages=8))
        e1 = c.absorb([5])
        e2 = c.absorb([5])
        assert c.pop_entry() is e1 and c.pop_entry() is e2
        # Out-of-order landings: the newer version wins regardless.
        c.page_durable(5, e2.versions[0])
        c.page_durable(5, e1.versions[0])
        assert c.durable[5] == e2.versions[0]
        assert not c.contains(5) and c.pending_pages == 0

    def test_watermarks(self):
        c = WriteCache(HostCacheConfig(capacity_pages=10, flush_high=0.5,
                                       flush_low=0.2))
        c.absorb([1, 2, 3, 4, 5, 6])
        assert c.need_flush()
        while not c.flushed_enough():
            c.pop_entry()
        assert c.dirty_pages <= 2
        # Flushing pages still hold capacity until they land.
        assert c.pending_pages == 6 and not c.can_absorb(5)

    def test_capacity_is_honest(self):
        c = WriteCache(HostCacheConfig(capacity_pages=4))
        assert c.fits(4) and not c.fits(5)
        c.absorb([0, 1, 2])
        assert not c.can_absorb(2)
        with pytest.raises(RuntimeError):
            c.absorb([7, 8])

    def test_lru_touch_reorders_flush(self):
        c = WriteCache(HostCacheConfig(capacity_pages=8,
                                       eviction="lru"))
        e1, e2, e3 = c.absorb([1]), c.absorb([2]), c.absorb([3])
        c.touch(1)                # read hit refreshes lpn 1's entry
        assert c.pop_entry() is e2
        assert c.pop_entry() is e3
        assert c.pop_entry() is e1
        assert c.pop_entry() is None

    def test_fifo_ignores_touch(self):
        c = WriteCache(HostCacheConfig(capacity_pages=8))
        e1, e2 = c.absorb([1]), c.absorb([2])
        c.touch(1)
        assert c.pop_entry() is e1 and c.pop_entry() is e2

    def test_lru_preserves_per_lpn_order_and_versions(self):
        # Two absorbed versions of one LPN: touch moves both entries to
        # the MRU end keeping their relative order, and even if their
        # programs land out of order the newer version stays durable.
        c = WriteCache(HostCacheConfig(capacity_pages=8,
                                       eviction="lru"))
        a, b = c.absorb([7]), c.absorb([7])
        c.touch(7)
        assert c.pop_entry() is a and c.pop_entry() is b
        c.page_durable(7, b.versions[0])
        c.page_durable(7, a.versions[0])
        assert c.durable[7] == b.versions[0]
        assert not c.contains(7) and c.pending_pages == 0

    def test_lru_flushing_lines_are_not_touchable(self):
        c = WriteCache(HostCacheConfig(capacity_pages=8,
                                       eviction="lru"))
        e1, e2 = c.absorb([1]), c.absorb([2])
        assert c.pop_entry() is e1          # lpn 1 now flushing-only
        c.touch(1)                          # must not corrupt the ring
        assert c.pop_entry() is e2

    def test_invalid_eviction_policy_rejected(self):
        with pytest.raises(ValueError, match="eviction"):
            HostCacheConfig(eviction="random")


class TestWriteCacheIntegration:
    HC = HostCacheConfig(capacity_pages=256)

    def test_absorbed_writes_complete_at_host_speed(self):
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", ncq_depth=8, host_cache=self.HC)
        assert stats.cache_absorbed_writes > 0
        assert stats.cache_stalled_writes == 0
        # Every absorbed page is eventually flushed, exactly once.
        assert stats.cache_flush_pages > 0
        no_cache = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                            gc="prepass", ncq_depth=8)
        assert stats.mean_us < no_cache.mean_us

    def test_read_hits_serve_from_dirty_lines(self):
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=600,
                         gc="prepass", ncq_depth=8, host_cache=self.HC)
        assert stats.cache_hit_pages > 0

    def test_tiny_cache_backpressures(self):
        tiny = HostCacheConfig(capacity_pages=8, flush_high=0.5,
                               flush_low=0.25)
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", ncq_depth=8, host_cache=tiny,
                         validate=True)
        assert stats.cache_stalled_writes > 0
        # Backpressure slows things down but never loses work: flush
        # traffic still covers every absorbed page by end of run (the
        # engine asserts the cache fully drains).
        assert stats.cache_flush_pages >= stats.cache_absorbed_writes

    @pytest.mark.parametrize("eviction", ["fifo", "lru"])
    def test_flush_traffic_preserves_wa_accounting(self, eviction):
        """Flushed programs run through the same FTL schedule: write
        amplification is identical with and without the cache — under
        either eviction policy (LRU permutes issue order, not volume)."""
        hc = HostCacheConfig(capacity_pages=256, eviction=eviction)
        with_ = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", ncq_depth=8, host_cache=hc)
        without = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                           gc="prepass", ncq_depth=8)
        assert with_.wa == without.wa
        assert with_.blocks_erased == without.blocks_erased

    def test_lru_end_to_end_drains_clean(self):
        """A full LRU-cache run with backpressure and validation on:
        the engine's drain asserts hold and flush volume still covers
        every absorbed page."""
        hc = HostCacheConfig(capacity_pages=32, flush_high=0.5,
                             flush_low=0.25, eviction="lru")
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", ncq_depth=8, host_cache=hc,
                         validate=True)
        assert stats.cache_absorbed_writes > 0
        assert stats.cache_flush_pages >= stats.cache_absorbed_writes


class TestFaultsClosedLoop:
    FC = FaultConfig(uncorrectable_prob=0.02, mispredict_scale=4.0,
                     escalation_attempts=2)

    def test_failure_set_is_queue_depth_invariant(self):
        """The fault plan is drawn per (seed, die) in admission order —
        the NCQ changes *when* ops run, never which ones fail."""
        open_ = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=600,
                         gc="prepass", faults=self.FC)
        for qd in (2, 16):
            closed = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=600,
                              gc="prepass", faults=self.FC, ncq_depth=qd)
            for f in FAULT_FIELDS:
                assert getattr(closed, f) == getattr(open_, f), f

    def test_faults_with_cache(self):
        stats = simulate("prn", AGED, "pr2ar2", seed=0, n_requests=400,
                         gc="prepass", faults=self.FC, ncq_depth=8,
                         host_cache=HostCacheConfig(capacity_pages=64),
                         validate=True)
        assert stats.unrecoverable == 0
        assert stats.cache_absorbed_writes > 0


class TestRunAPIsAndJournal:
    def test_compare_and_batch_take_the_knob(self):
        grid = compare_mechanisms(
            "websearch", AGED, mechanisms=("baseline", "pr2ar2"), seed=0,
            n_requests=200, ncq_depth=8,
        )
        assert all(g.max_inflight >= 1 for g in grid.values())
        batch = simulate_batch(
            "websearch", (AGED,), mechanisms=("pr2ar2",), seeds=(0,),
            n_requests=200, ncq_depth=8,
        )
        assert next(iter(batch.values())).max_inflight >= 1

    def test_sweep_workers_agree_closed_loop(self):
        kw = dict(workload="prn", conditions=(AGED,),
                  mechanisms=("baseline", "pr2ar2"), seeds=(0, 1),
                  n_requests=200, gc="prepass", ncq_depth=8,
                  host_cache=HostCacheConfig(capacity_pages=64))
        assert sweep_to_json(run_sweep(**kw, workers=1)) == \
            sweep_to_json(run_sweep(**kw, workers=2))

    def test_journal_resume_round_trips_closed_loop(self, tmp_path):
        kw = dict(workload="prn", conditions=(AGED,),
                  mechanisms=("pr2ar2",), seeds=(0, 1), n_requests=200,
                  gc="prepass", ncq_depth=4)
        j = tmp_path / "sweep.jsonl"
        first = run_sweep(**kw, journal=j)
        resumed = run_sweep(**kw, journal=j)   # replayed entirely
        assert sweep_to_json(first) == sweep_to_json(resumed)

    def test_journal_decode_tolerates_old_schema(self):
        """A journal written before the closed-loop fields existed must
        still decode (missing keys take their zero defaults)."""
        full = dataclasses.asdict(
            simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=100)
        )
        old = {k: v for k, v in full.items() if k not in CLOSED_FIELDS}
        stats = _stats_from_journal(old)
        assert isinstance(stats, SimStats)
        assert stats.max_inflight == 0 and stats.throughput_iops == 0.0
        assert stats.mean_us == full["mean_us"]

    def test_journal_decode_tolerates_future_schema(self):
        """...and one written by a FUTURE build (keys we don't know yet)
        must decode too, dropping the unknown keys."""
        full = dataclasses.asdict(
            simulate("websearch", AGED, "pr2ar2", seed=0, n_requests=100)
        )
        full["some_future_counter"] = 7
        stats = _stats_from_journal(full)
        assert stats.mean_us == full["mean_us"]
        assert not hasattr(stats, "some_future_counter")
