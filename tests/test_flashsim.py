"""Discrete-event SSD simulator invariants + mechanism orderings."""

import numpy as np
import pytest

# Heavyweight DES lane: mechanism-ordering runs need the full aged-condition
# characterization (AR² grid search).  The fast lane's DES coverage lives in
# test_flashsim_equiv.py.
pytestmark = pytest.mark.slow

from repro.core.retry import RetryPolicy
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition
from repro.flashsim.ssd import SSDSim, compare_mechanisms, simulate
from repro.flashsim.workloads import PROFILES, generate_trace, make_workloads

COND = OperatingCondition(365.0, 1000.0)
W = make_workloads()["websearch"]
N = 1200


@pytest.fixture(scope="module")
def stats_by_mechanism():
    return compare_mechanisms(W, COND, seed=3, n_requests=N)


class TestOrderings:
    def test_pr2_beats_baseline(self, stats_by_mechanism):
        s = stats_by_mechanism
        assert s["pr2"].mean_us < s["baseline"].mean_us

    def test_ar2_beats_baseline(self, stats_by_mechanism):
        s = stats_by_mechanism
        assert s["ar2"].mean_us < s["baseline"].mean_us

    def test_combined_beats_each(self, stats_by_mechanism):
        s = stats_by_mechanism
        assert s["pr2ar2"].mean_us < s["pr2"].mean_us
        assert s["pr2ar2"].mean_us < s["ar2"].mean_us

    def test_sota_complementarity(self, stats_by_mechanism):
        """The paper's complementarity claim: PR2+AR2 stacks on SOTA."""
        s = stats_by_mechanism
        assert s["sota+pr2ar2"].mean_us < s["sota"].mean_us
        assert s["sota+pr2ar2"].mean_us < s["pr2ar2"].mean_us

    def test_attempt_counts_mechanism_invariant(self, stats_by_mechanism):
        """PR2 changes step latency, not step count (paper's design goal)."""
        s = stats_by_mechanism
        assert s["pr2"].mean_read_attempts == pytest.approx(
            s["baseline"].mean_read_attempts, rel=0.02
        )
        # AR2's characterized scale keeps attempts within the search budget.
        assert s["pr2ar2"].mean_read_attempts <= s["baseline"].mean_read_attempts + 0.5


class TestDESBasics:
    def test_percentile_ordering(self, stats_by_mechanism):
        for st in stats_by_mechanism.values():
            assert st.p50_us <= st.p95_us <= st.p99_us
            assert st.die_util <= 1.0 and st.channel_util <= 1.0

    def test_fresh_condition_is_fast(self):
        fresh = simulate(W, OperatingCondition(0.0, 0.0), "baseline",
                         n_requests=N)
        aged = simulate(W, COND, "baseline", n_requests=N)
        assert fresh.mean_us < aged.mean_us
        assert fresh.mean_read_attempts < aged.mean_read_attempts

    def test_trace_determinism(self):
        t1 = generate_trace(W, seed=5)
        t2 = generate_trace(W, seed=5)
        np.testing.assert_array_equal(t1.arrival_us, t2.arrival_us)
        np.testing.assert_array_equal(t1.start_page, t2.start_page)

    def test_trace_stats_match_profile(self):
        t = generate_trace(W, seed=0)
        assert abs(t.is_read.mean() - W.read_ratio) < 0.02
        gaps = np.diff(t.arrival_us)
        assert np.mean(gaps) == pytest.approx(1e6 / W.iops, rel=0.25)

    def test_writes_dilute_the_win(self):
        """On a mixed workload the read-only response-time reduction must
        exceed the overall reduction — writes are mechanism-invariant."""
        prxy = make_workloads()["prxy"]
        px = compare_mechanisms(
            prxy, COND, mechanisms=("baseline", "pr2ar2"), n_requests=N
        )
        red_read = 1 - px["pr2ar2"].read_mean_us / px["baseline"].read_mean_us
        red_all = 1 - px["pr2ar2"].mean_us / px["baseline"].mean_us
        assert red_read > red_all > 0
