"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.kv_retry.kernel import kv_retry_pallas
from repro.kernels.kv_retry.ops import quantize_pages
from repro.kernels.kv_retry.ref import kv_retry_ref
from repro.kernels.rber.ops import rber_table
from repro.kernels.rber.ref import rber_ref
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-4, rtol=2e-4
    )


class TestFlashAttention:
    @pytest.mark.parametrize("T,S,hd,causal,window", [
        (64, 64, 16, True, None),
        (100, 100, 32, True, None),     # non-multiple of block
        (64, 192, 16, False, None),     # cross-ish (T != S)
        (128, 128, 16, True, 32),       # sliding window
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_vs_ref(self, T, S, hd, causal, window, dtype):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        BH = 4
        q = jax.random.normal(k1, (BH, T, hd), dtype)
        k = jax.random.normal(k2, (BH, S, hd), dtype)
        v = jax.random.normal(k3, (BH, S, hd), dtype)
        out = flash_attention_fwd(
            q, k, v, causal=causal, window=window, bq=32, bk=32, interpret=True
        )
        ref = attention_ref(q, k, v, causal=causal, window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **_tol(dtype),
        )

    def test_softcap(self):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        q = 3.0 * jax.random.normal(k1, (2, 64, 16), jnp.float32)
        k = 3.0 * jax.random.normal(k2, (2, 64, 16), jnp.float32)
        v = jax.random.normal(k3, (2, 64, 16), jnp.float32)
        out = flash_attention_fwd(
            q, k, v, causal=True, softcap=20.0, bq=32, bk=32, interpret=True
        )
        ref = attention_ref(q, k, v, causal=True, softcap=20.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4, rtol=3e-4)

    def test_gqa_grouping(self):
        """BH != BK exercises the kv-head index map (G = BH // BK)."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(k1, (8, 64, 16), jnp.float32)   # 8 q-head rows
        k = jax.random.normal(k2, (2, 64, 16), jnp.float32)   # 2 kv-head rows
        v = jax.random.normal(k3, (2, 64, 16), jnp.float32)
        out = flash_attention_fwd(q, k, v, causal=True, bq=32, bk=32, interpret=True)
        kk = jnp.repeat(k, 4, axis=0)
        vv = jnp.repeat(v, 4, axis=0)
        ref = attention_ref(q, kk, vv, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


class TestSSDScan:
    @pytest.mark.parametrize("T,chunk,hd,ds", [
        (64, 16, 16, 32),
        (100, 32, 16, 32),    # padding path
        (128, 128, 32, 64),   # single chunk
    ])
    def test_vs_sequential_ref(self, T, chunk, hd, ds):
        B, nh = 2, 3
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (B, T, nh, hd), jnp.float32)
        Bm = 0.5 * jax.random.normal(ks[1], (B, T, ds), jnp.float32)
        Cm = 0.5 * jax.random.normal(ks[2], (B, T, ds), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T, nh)))
        A = -jnp.exp(jax.random.normal(ks[4], (nh,)))
        y, H = ssd_scan(x, Bm, Cm, dt, A, chunk=chunk, interpret=True)

        xh = x.transpose(0, 2, 1, 3).reshape(B * nh, T, hd)
        dth = dt.transpose(0, 2, 1).reshape(B * nh, T)
        dAh = dth * jnp.tile(A, B)[:, None]
        Bh = jnp.broadcast_to(Bm[:, None], (B, nh, T, ds)).reshape(B * nh, T, ds)
        Ch = jnp.broadcast_to(Cm[:, None], (B, nh, T, ds)).reshape(B * nh, T, ds)
        yr, Hr = ssd_scan_ref(xh, Bh, Ch, dth, dAh)
        yr = yr.reshape(B, nh, T, hd).transpose(0, 2, 1, 3)
        Hr = Hr.reshape(B, nh, ds, hd).transpose(0, 1, 3, 2)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(np.asarray(H), np.asarray(Hr), atol=3e-4, rtol=3e-4)

    def test_matches_model_chunked_path(self):
        from repro.models.ssm import ssd_chunked

        B, T, nh, hd, ds = 1, 96, 2, 16, 32
        ks = jax.random.split(jax.random.PRNGKey(7), 5)
        x = jax.random.normal(ks[0], (B, T, nh, hd), jnp.float32)
        Bm = 0.5 * jax.random.normal(ks[1], (B, T, ds), jnp.float32)
        Cm = 0.5 * jax.random.normal(ks[2], (B, T, ds), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, T, nh)))
        A = -jnp.exp(jax.random.normal(ks[4], (nh,)))
        y1, H1 = ssd_scan(x, Bm, Cm, dt, A, chunk=32, interpret=True)
        y2, H2 = ssd_chunked(x, Bm, Cm, dt, A, 32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=3e-4, rtol=3e-4)
        np.testing.assert_allclose(np.asarray(H1), np.asarray(H2), atol=3e-4, rtol=3e-4)


class TestRBERKernel:
    @pytest.mark.parametrize("n_pages,n_steps", [(32, 8), (100, 41)])
    def test_vs_ref(self, n_pages, n_steps):
        key = jax.random.PRNGKey(0)
        mu = jax.random.normal(key, (n_pages, 8)) * 0.05 + jnp.arange(8.0)
        sigma = 0.1 + 0.01 * jax.random.uniform(
            jax.random.fold_in(key, 1), (n_pages, 8)
        )
        levels = jnp.linspace(0.3, 6.5, 7)[None, :] - 0.01 * jnp.arange(
            n_steps, dtype=jnp.float32
        )[:, None]                                    # (S, 7)
        out = rber_table(mu, sigma, levels, interpret=True)
        ref = rber_ref(mu, sigma, levels)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-4
        )


class TestKVRetry:
    @pytest.mark.parametrize("P,E", [(64, 64), (100, 128), (7, 32)])
    @pytest.mark.parametrize("tau", [0.01, 0.05])
    def test_vs_ref(self, P, E, tau):
        key = jax.random.PRNGKey(3)
        x = jax.random.normal(key, (P, E), jnp.float32)
        q, s = quantize_pages(x)
        out, margin = kv_retry_pallas(q, s, x, tau=tau, bp=32, interpret=True)
        out_r, margin_r = kv_retry_ref(q, s, x, tau=tau)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(margin), np.asarray(margin_r), atol=1e-5, rtol=1e-4
        )

    def test_retry_pages_get_exact_backing(self):
        key = jax.random.PRNGKey(4)
        x = jax.random.normal(key, (64, 32), jnp.float32)
        # huge outlier rows -> large scale -> margin < 0 -> backing
        x = x.at[::4].mul(1e4)
        q, s = quantize_pages(x)
        out, margin = kv_retry_pallas(q, s, x, tau=0.001, bp=32, interpret=True)
        retried = np.asarray(margin[:, 0]) < 0
        assert retried.any()
        np.testing.assert_array_equal(
            np.asarray(out)[retried], np.asarray(x)[retried]
        )

    def test_quantization_error_within_bound(self):
        key = jax.random.PRNGKey(5)
        x = jax.random.normal(key, (128, 64), jnp.float32)
        q, s = quantize_pages(x)
        err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
        assert (err <= np.asarray(s) * 0.5 + 1e-7).all()
