"""Sharded-runtime contracts: shard equivalence + worker determinism.

The two acceptance properties of the sharded simulation runtime
(ISSUE 5):

  * **Shard equivalence** — the per-channel sharded event core
    (``shard=True``) produces *exactly* the monolithic engine's SimStats
    (full dataclass equality, GC counters included) across every
    scheduler x GC-mode combination, on synthetic traces and on both
    checked-in MSR-format excerpts.
  * **Worker determinism** — ``simulate_batch`` through the process-pool
    sweep executor returns identical cells in identical order for any
    worker count: the canonical JSON serialization is byte-identical
    for ``workers in {1, 2, 4}``.
"""

import dataclasses
import json

import pytest

from repro.core.retry import RetryPolicy
from repro.flashsim.config import (
    DEFAULT_SSD,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.engine import merge_shard_results
from repro.flashsim.runtime import (
    Cell,
    host_fingerprint,
    run_cells,
    sweep_cell_key,
    sweep_to_json,
)
from repro.flashsim.sched import SCHEDULERS
from repro.flashsim.ssd import (
    SSDSim,
    _with_knobs,
    compare_mechanisms,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import cached_trace, make_workloads

AGED = OperatingCondition(365.0, 1000.0)
MODEST = OperatingCondition(30.0, 0.0)

GC_MODES = ("off", "prepass", "online")

#: Checked-in MSR-format excerpts (resolved via the tests/data search
#: path fallback baked into the workload registry).
MSR_EXCERPTS = ("msr:web_0", "msr:src1_1")


class TestShardEquivalence:
    """shard=True must be bit-identical to the monolithic event core."""

    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    @pytest.mark.parametrize("gc", GC_MODES)
    def test_synthetic_all_scheduler_gc_combos(self, scheduler, gc):
        """Full SimStats equality (== over every field, GC counters and
        suspension counts included) on a GC-churning write-heavy trace."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=800)
        trace = cached_trace(w, seed=1)
        cfg = _with_knobs(DEFAULT_SSD, scheduler, gc)
        mono = SSDSim(cfg, AGED, RetryPolicy("pr2ar2"), seed=9).run(trace)
        shrd = SSDSim(cfg, AGED, RetryPolicy("pr2ar2"), seed=9).run(
            trace, shard=True)
        assert mono == shrd

    @pytest.mark.parametrize("spec", MSR_EXCERPTS)
    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    @pytest.mark.parametrize("gc", GC_MODES)
    def test_msr_excerpts_all_scheduler_gc_combos(self, spec, scheduler, gc):
        """Both checked-in MSR-format excerpts, ingested end-to-end
        (dense remap + FTL auto-sizing), sharded vs monolithic."""
        a = simulate(spec, AGED, "pr2ar2", seed=0, n_requests=600,
                     scheduler=scheduler, gc=gc)
        b = simulate(spec, AGED, "pr2ar2", seed=0, n_requests=600,
                     scheduler=scheduler, gc=gc, shard=True)
        assert a == b

    @pytest.mark.parametrize("mechanism", ["baseline", "pr2", "sota+pr2ar2"])
    def test_mechanisms_and_conditions(self, mechanism):
        """Serial and pipelined read state machines, aged and modest."""
        w = make_workloads()["websearch"]
        for cond in (AGED, MODEST):
            a = simulate(w, cond, mechanism, seed=3, n_requests=500)
            b = simulate(w, cond, mechanism, seed=3, n_requests=500,
                         shard=True)
            assert a == b

    def test_nondefault_geometry(self):
        """Sharding follows the configured channel count, not the
        default 8 — 2x4 and 1x8 (single channel short-circuits)."""
        w = dataclasses.replace(make_workloads()["prxy"], n_requests=400)
        for cfg in (SSDConfig(n_channels=2, dies_per_channel=4),
                    SSDConfig(n_channels=1, dies_per_channel=8)):
            a = simulate(w, AGED, "pr2ar2", seed=0, cfg=cfg)
            b = simulate(w, AGED, "pr2ar2", seed=0, cfg=cfg, shard=True)
            assert a == b

    def test_per_request_completions_match(self):
        """Stronger than SimStats: the merged completion stream equals
        the monolithic one at every request."""
        import numpy as np

        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=800)
        trace = cached_trace(w, seed=0)
        cfg = _with_knobs(DEFAULT_SSD, "host_prio", "online")
        mono = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
        shrd = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
        mono.run(trace)
        shrd.run(trace, shard=True)
        np.testing.assert_array_equal(mono.last_req_done_us,
                                      shrd.last_req_done_us)

    def test_sharded_work_conservation_validated(self):
        """The engine's per-step work-conservation assertion holds inside
        every shard loop."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=600)
        trace = cached_trace(w, seed=1)
        for scheduler in ("fcfs", "preempt"):
            cfg = _with_knobs(DEFAULT_SSD, scheduler, "online")
            sim = SSDSim(cfg, AGED, RetryPolicy("pr2ar2"), seed=9)
            stats = sim.run(trace, validate=True, shard=True)
            assert stats.n_requests == 600

    def test_reference_engine_rejects_shard(self):
        w = make_workloads()["websearch"]
        with pytest.raises(NotImplementedError, match="shard"):
            simulate(w, AGED, "baseline", seed=0, n_requests=100,
                     engine="reference", shard=True)
        with pytest.raises(NotImplementedError, match="shard"):
            simulate_batch(w, (AGED,), mechanisms=("baseline",),
                           seeds=(0,), n_requests=100,
                           engine="reference", shard=True)

    def test_merge_requires_one_result_per_channel(self):
        with pytest.raises(ValueError, match="per channel"):
            merge_shard_results(DEFAULT_SSD, [])


class TestWorkerDeterminism:
    """simulate_batch output must be byte-identical for any workers."""

    def _sweep(self, workers, shard=False):
        w = make_workloads()["websearch"]
        return simulate_batch(
            w, (AGED, MODEST), mechanisms=("baseline", "pr2ar2"),
            seeds=(0, 1, 2), n_requests=300, workers=workers, shard=shard,
        )

    def test_workers_1_2_4_byte_identical(self):
        blobs = {wk: sweep_to_json(self._sweep(wk)) for wk in (1, 2, 4)}
        assert blobs[1] == blobs[2] == blobs[4]
        # and the serialization is loadable, fully keyed JSON
        payload = json.loads(blobs[1])
        assert len(payload) == 2 * 2 * 3

    def test_key_order_is_canonical(self):
        """Dict iteration order (seed -> condition -> mechanism) matches
        the inline sweep's insertion order for every worker count."""
        assert list(self._sweep(1)) == list(self._sweep(4))

    def test_workers_compose_with_shard(self):
        assert sweep_to_json(self._sweep(1)) == \
            sweep_to_json(self._sweep(2, shard=True))

    def test_inline_fallback_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_SWEEP_INLINE", "1")
        forced = self._sweep(4)
        monkeypatch.delenv("REPRO_SWEEP_INLINE")
        assert sweep_to_json(forced) == sweep_to_json(self._sweep(1))

    def test_reference_engine_workers_match_inline(self):
        """The seed-group fan-out is engine-agnostic: the reference
        engine parallelizes too (each worker re-enters the inline
        path), with identical cells."""
        w = make_workloads()["websearch"]
        kw = dict(mechanisms=("baseline",), seeds=(0, 1), n_requests=150,
                  engine="reference")
        a = simulate_batch(w, (AGED,), **kw)
        b = simulate_batch(w, (AGED,), workers=2, **kw)
        assert a == b
        assert list(a) == list(b)

    def test_sweep_cell_key_full_float_precision(self):
        """Conditions differing past 6 significant digits must not
        collapse to one JSON key (repr precision, not %g)."""
        c1 = OperatingCondition(365.00001, 0.0)
        c2 = OperatingCondition(365.00002, 0.0)
        assert sweep_cell_key("baseline", c1, 0) != \
            sweep_cell_key("baseline", c2, 0)

    def test_compare_mechanisms_workers_match_inline(self):
        w = make_workloads()["prn"]
        a = compare_mechanisms(w, AGED, mechanisms=("baseline", "pr2ar2"),
                               seed=0, n_requests=400, gc="prepass")
        b = compare_mechanisms(w, AGED, mechanisms=("baseline", "pr2ar2"),
                               seed=0, n_requests=400, gc="prepass",
                               workers=2)
        assert a == b
        assert list(a) == list(b)


class TestCellExecutor:
    def test_results_in_input_order(self):
        w = make_workloads()["websearch"]
        cells = [
            Cell("simulate", w, (AGED,), ("baseline",), seed, DEFAULT_SSD,
                 n_requests=200)
            for seed in (3, 1, 2)
        ]
        par = run_cells(cells, workers=3)
        inline = run_cells(cells, workers=1)
        assert par == inline
        # distinct seeds produce distinct traces -> distinct stats, so
        # positional equality above proves ordering, not just content
        assert len({s.mean_us for s in inline}) == 3

    def test_chunked_submission_payload_equality(self):
        """Chunking regression contract: pool submission groups several
        cells per task (amortizing per-cell IPC — the BENCH-recorded
        0.92x small-cell slowdown), and the payload stays byte-identical
        to the unchunked inline run for any worker count."""
        from repro.flashsim.runtime import _chunk_pending

        w = make_workloads()["websearch"]
        cells = [
            Cell("simulate", w, (AGED,), (m,), seed, DEFAULT_SSD,
                 n_requests=120)
            for seed in range(5) for m in ("baseline", "pr2ar2")
        ]
        # chunking really happens: 10 cells over 2 workers -> fewer
        # tasks than cells, every cell present exactly once, in order
        chunks = _chunk_pending(dict(enumerate(cells)), workers=2)
        assert len(chunks) < len(cells)
        flat = [i for ch in chunks for i, _ in ch]
        assert flat == list(range(len(cells)))
        blobs = {}
        for wk in (1, 2, 3):
            rs = run_cells(cells, workers=wk)
            blobs[wk] = json.dumps(
                [dataclasses.asdict(r) for r in rs], sort_keys=True)
        assert blobs[1] == blobs[2] == blobs[3]

    def test_cell_kind_validation(self):
        w = make_workloads()["websearch"]
        with pytest.raises(ValueError, match="kind"):
            Cell("fanout", w, (AGED,), ("baseline",), 0)
        with pytest.raises(ValueError, match="one mechanism"):
            Cell("simulate", w, (AGED,), ("baseline", "pr2"), 0)
        with pytest.raises(ValueError, match="one condition"):
            Cell("compare", w, (AGED, MODEST), ("baseline",), 0)

    def test_cell_errors_propagate(self):
        w = make_workloads()["websearch"]
        bad = Cell("simulate", w, (AGED,), ("no-such-mechanism",), 0,
                   n_requests=50)
        with pytest.raises(ValueError):
            run_cells([bad], workers=1)
        with pytest.raises(ValueError):
            run_cells([bad, bad], workers=2)

    def test_sweep_cell_keys_unique(self):
        keys = {
            sweep_cell_key(m, c, s)
            for m in ("baseline", "pr2ar2")
            for c in (AGED, MODEST, OperatingCondition(365.0, 0.0))
            for s in (0, 1)
        }
        assert len(keys) == 12

    def test_host_fingerprint_fields(self):
        fp = host_fingerprint()
        assert set(fp) == {"cpu_model", "cpu_count", "platform", "python",
                           "numpy"}
        assert fp["cpu_count"] >= 1
