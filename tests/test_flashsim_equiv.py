"""Array event-core vs seed closure engine: equivalence + invariants.

The array engine must reproduce the retired seed engine bit-for-bit on
fixed traces (same trace, same RNG stream for attempt sampling, same
event semantics), and its resource accounting must stay physical under
any (workload, mechanism, seed) combination.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.retry import RetryPolicy
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition
from repro.flashsim.engine_ref import SSDSimRef
from repro.flashsim.ssd import (
    SSDSim,
    compare_mechanisms,
    expand_trace,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import (
    RequestTrace,
    cached_trace,
    generate_trace,
    make_workloads,
)

AGED = OperatingCondition(365.0, 1000.0)
MODEST = OperatingCondition(30.0, 0.0)

STAT_FIELDS = (
    "mean_us", "p50_us", "p95_us", "p99_us", "read_mean_us", "read_p99_us",
    "n_requests", "mean_read_attempts", "die_util", "channel_util",
)


def _stats_tuple(s):
    return tuple(getattr(s, f) for f in STAT_FIELDS)


class TestSeedEquivalence:
    """The regression contract: array engine == seed engine, exactly.

    Cells cover serial reads (baseline/sota/ar2), the PR² pipelined state
    machine, and the write path (prxy is 45% writes).  Equal-timestamp
    tie-breaking can differ between the engines in rare cascades (see the
    ssd.py module docstring), so the regression pins specific known-exact
    trace cells; the distributional agreement test below covers the rest.
    """

    @pytest.mark.parametrize("workload", ["websearch", "prxy"])
    @pytest.mark.parametrize(
        "mechanism", ["baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2"]
    )
    def test_exact_simstats_match(self, workload, mechanism):
        w = make_workloads()[workload]
        a = simulate(w, AGED, mechanism, seed=0, n_requests=400,
                     engine="array")
        r = simulate(w, AGED, mechanism, seed=0, n_requests=400,
                     engine="reference")
        assert _stats_tuple(a) == _stats_tuple(r)

    def test_exact_match_modest_condition(self):
        w = make_workloads()["oltp"]
        for mech in ("baseline", "pr2ar2"):
            a = simulate(w, MODEST, mech, seed=3, n_requests=400,
                         engine="array")
            r = simulate(w, MODEST, mech, seed=3, n_requests=400,
                         engine="reference")
            assert _stats_tuple(a) == _stats_tuple(r)

    def test_per_request_completion_times_match(self):
        """Stronger than SimStats: every request finishes at the same
        microsecond in both engines (serial + pipelined)."""
        w = dataclasses.replace(make_workloads()["prxy"], n_requests=400)
        trace = cached_trace(w, seed=0)
        for mech in ("baseline", "pr2ar2"):
            a = SSDSim(condition=AGED, policy=RetryPolicy(mech), seed=7)
            r = SSDSimRef(condition=AGED, policy=RetryPolicy(mech), seed=7)
            a.run(trace)
            r.run(trace)
            np.testing.assert_array_equal(a.last_req_done_us,
                                          r.last_req_done_us)

    def test_unsorted_trace_matches_reference(self):
        """Externally-supplied traces need not be time-sorted: the
        admission cursor stable-sorts arrivals and must still reproduce
        the order-agnostic heap engine exactly."""
        w = dataclasses.replace(make_workloads()["oltp"], n_requests=300)
        t = generate_trace(w, seed=5)
        perm = np.random.default_rng(1).permutation(300)
        shuffled = RequestTrace(
            t.arrival_us[perm], t.is_read[perm],
            t.n_pages[perm], t.start_page[perm],
        )
        for mech in ("baseline", "pr2ar2"):
            a = SSDSim(condition=AGED, policy=RetryPolicy(mech), seed=7)
            r = SSDSimRef(condition=AGED, policy=RetryPolicy(mech), seed=7)
            sa = a.run(shuffled)
            sr = r.run(shuffled)
            assert sa.mean_us > 0
            assert _stats_tuple(sa) == _stats_tuple(sr)

    @pytest.mark.parametrize("workload", ["prn", "rsrch"])
    def test_write_heavy_presets_match_reference(self, workload):
        """The FTL-regime write-heavy presets must still agree exactly
        between engines with GC *off* (the surface both implement): the
        kind-generalized event loop may not perturb the in-place path."""
        w = make_workloads()[workload]
        for mech in ("baseline", "pr2ar2"):
            a = simulate(w, AGED, mech, seed=0, n_requests=400,
                         engine="array")
            r = simulate(w, AGED, mech, seed=0, n_requests=400,
                         engine="reference")
            assert _stats_tuple(a) == _stats_tuple(r)
            assert a.wa == r.wa == 1.0
            assert a.gc_invocations == r.gc_invocations == 0

    def test_batched_sampler_matches_per_request_stream(self):
        """The batched attempt sampler consumes the RNG exactly like the
        seed's per-request sampler, so attempt statistics are identical."""
        w = make_workloads()["websearch"]
        for seed in (0, 11):
            a = simulate(w, AGED, "baseline", seed=seed, n_requests=600,
                         engine="array")
            r = simulate(w, AGED, "baseline", seed=seed, n_requests=600,
                         engine="reference")
            assert a.mean_read_attempts == r.mean_read_attempts

    def test_distributional_agreement_across_grid(self):
        """Where exact tie-breaking differs, distributions must not: mean
        response agrees to 0.5% on every grid cell."""
        mk = make_workloads()
        for wname in ("usr", "graph"):
            for mech in ("baseline", "pr2ar2"):
                for seed in (0, 1):
                    a = simulate(mk[wname], AGED, mech, seed=seed,
                                 n_requests=500, engine="array")
                    r = simulate(mk[wname], AGED, mech, seed=seed,
                                 n_requests=500, engine="reference")
                    assert a.mean_us == pytest.approx(r.mean_us, rel=5e-3)
                    assert a.mean_read_attempts == r.mean_read_attempts


class TestEngineInvariants:
    """Physicality of the array engine's resource accounting."""

    @pytest.mark.parametrize("workload", ["websearch", "oltp", "prxy"])
    @pytest.mark.parametrize("mechanism", ["baseline", "pr2ar2", "sota"])
    @pytest.mark.parametrize("seed", [0, 5])
    def test_utilization_in_unit_interval(self, workload, mechanism, seed):
        w = make_workloads()[workload]
        s = simulate(w, AGED, mechanism, seed=seed, n_requests=400)
        assert 0.0 <= s.die_util <= 1.0
        assert 0.0 <= s.channel_util <= 1.0

    def test_completion_after_arrival(self):
        w = dataclasses.replace(make_workloads()["oltp"], n_requests=400)
        trace = cached_trace(w, seed=2)
        sim = SSDSim(condition=AGED, policy=RetryPolicy("pr2ar2"), seed=9)
        sim.run(trace)
        assert (sim.last_req_done_us >= trace.arrival_us).all()

    def test_expansion_is_mechanism_independent(self):
        w = dataclasses.replace(make_workloads()["usr"], n_requests=300)
        trace = cached_trace(w, seed=4)
        ex = expand_trace(trace)
        assert ex.n_ops == int(trace.n_pages.sum())
        assert (ex.chan == ex.die % DEFAULT_SSD.n_channels).all()
        # shared-expansion run == private-expansion run
        a = SSDSim(condition=AGED, policy=RetryPolicy("pr2"), seed=7)
        b = SSDSim(condition=AGED, policy=RetryPolicy("pr2"), seed=7)
        assert _stats_tuple(a.run(trace, expansion=ex)) == \
            _stats_tuple(b.run(trace))


class TestRunAPI:
    def test_compare_mechanisms_shares_trace(self):
        """All mechanisms must see the same arrivals (one generated trace)."""
        w = make_workloads()["websearch"]
        stats = compare_mechanisms(
            w, AGED, mechanisms=("baseline", "pr2"), seed=0, n_requests=300
        )
        explicit = simulate(
            w, AGED, "baseline", seed=0,
            trace=cached_trace(
                dataclasses.replace(w, n_requests=300), seed=0
            ),
        )
        assert _stats_tuple(stats["baseline"]) == _stats_tuple(explicit)

    def test_simulate_trace_param(self):
        w = dataclasses.replace(make_workloads()["ycsb-b"], n_requests=250)
        trace = generate_trace(w, seed=1)
        s1 = simulate(w, AGED, "baseline", seed=1, trace=trace)
        s2 = simulate(w, AGED, "baseline", seed=1, n_requests=250)
        assert _stats_tuple(s1) == _stats_tuple(s2)

    def test_simulate_batch_grid(self):
        w = make_workloads()["websearch"]
        conds = (AGED, MODEST)
        mechs = ("baseline", "pr2ar2")
        seeds = (0, 1)
        out = simulate_batch(w, conds, mechanisms=mechs, seeds=seeds,
                             n_requests=250)
        assert set(out) == {
            (m, c, s) for m in mechs for c in conds for s in seeds
        }
        # batch cells match individually-run cells
        for (m, c, s), st in out.items():
            solo = simulate(w, c, m, seed=s, n_requests=250)
            assert _stats_tuple(st) == _stats_tuple(solo)

    def test_trace_cache_returns_same_object(self):
        w = dataclasses.replace(make_workloads()["graph"], n_requests=123)
        t1 = cached_trace(w, seed=0)
        t2 = cached_trace(w, seed=0)
        assert t1 is t2
        assert not t1.arrival_us.flags.writeable

    def test_trace_stable_across_hash_salt(self):
        """CRC32-salted traces: reproducible irrespective of PYTHONHASHSEED
        (str ``hash()`` is salted per process — the seed engine's traces
        silently differed between runs).  Pinned values catch any change
        to the generation stream."""
        w = dataclasses.replace(make_workloads()["websearch"], n_requests=64)
        t = generate_trace(w, seed=0)
        assert float(t.arrival_us[0]) == pytest.approx(2.6534492570950823)
        assert float(t.arrival_us[-1]) == pytest.approx(1989.2930163687506)
        t2 = generate_trace(w, seed=0)
        np.testing.assert_array_equal(t.arrival_us, t2.arrival_us)
