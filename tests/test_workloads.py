"""Workload-subsystem tests: synthetic bit-parity, schema validation,
transforms, ingestion, the registry, and generator-shape validation.

The refactor contract (ISSUE 4): moving the synthetic generator into the
``workloads`` package must be invisible — ``generate_trace`` /
``cached_trace`` arrays are pinned bit-for-bit against checksums
recorded from the pre-refactor module (``tests/data/
golden_workloads.json``), and a pinned ``compare_mechanisms`` cell must
reproduce its pre-refactor stats exactly.
"""

from __future__ import annotations

import dataclasses
import gzip
import hashlib
import json
import pathlib

import numpy as np
import pytest

from repro.flashsim import (
    GCConfig,
    OperatingCondition,
    SSDConfig,
    compare_mechanisms,
    resolve_trace,
    simulate,
)
from repro.flashsim.ftl import build_ftl_schedule
from repro.flashsim.workloads import (
    GC_PROFILES,
    PROFILES,
    DenseRemap,
    FileSource,
    RequestTrace,
    RWFilter,
    Subsample,
    SyntheticSource,
    TimeRescale,
    Truncate,
    Window,
    cached_trace,
    generate_trace,
    get_source,
    load_blktrace_txt,
    load_msr_csv,
    make_workloads,
    register_source,
    touched_pages,
    trace_stats,
)

DATA = pathlib.Path(__file__).resolve().parent / "data"
GOLDEN = json.loads((DATA / "golden_workloads.json").read_text())
AGED = OperatingCondition(365.0, 1000.0)


def _trace_sha(t: RequestTrace) -> str:
    h = hashlib.sha256()
    for a in (t.arrival_us, t.is_read, t.n_pages, t.start_page):
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def _valid_trace(n=8, **over):
    kw = dict(
        arrival_us=np.linspace(0.0, 700.0, n),
        is_read=np.arange(n) % 2 == 0,
        n_pages=np.full(n, 2, np.int64),
        start_page=np.arange(n, dtype=np.int64) * 10,
    )
    kw.update(over)
    return RequestTrace(**kw)


class TestSyntheticBitParity:
    """Acceptance: the package generator is the pre-refactor generator."""

    @pytest.mark.parametrize("w", PROFILES + GC_PROFILES,
                             ids=lambda w: w.name)
    def test_generate_trace_matches_pre_refactor_checksums(self, w):
        for seed in range(5):
            got = _trace_sha(generate_trace(w, seed=seed))
            assert got == GOLDEN["trace_sha"][f"{w.name}:{seed}"], (
                f"{w.name} seed {seed}: synthetic trace drifted from the "
                f"pre-refactor module"
            )

    def test_cached_trace_matches_generate_trace(self):
        w = make_workloads()["oltp"]
        assert _trace_sha(cached_trace(w, seed=2)) == \
            _trace_sha(generate_trace(w, seed=2))

    def test_source_with_no_transforms_is_the_cached_trace(self):
        w = make_workloads()["websearch"]
        assert SyntheticSource(w).trace(1) is cached_trace(w, seed=1)

    def test_pinned_compare_mechanisms_cell(self):
        """The pre-refactor stats of one plain cell, bit-for-bit.

        Pinned fields only: SimStats grows new (zero-defaulted) counters
        over time — the contract is that every *pre-refactor* value is
        untouched, not that no fields were added since the pin.
        """
        w = dataclasses.replace(make_workloads()["websearch"],
                                n_requests=400)
        grid = compare_mechanisms(w, AGED, mechanisms=("baseline", "pr2ar2"),
                                  seed=3)
        for mech, want in GOLDEN["compare_plain"].items():
            got = dataclasses.asdict(grid[mech])
            for field, v in want.items():
                assert got[field] == v, (
                    f"{mech}.{field}: stats drifted from pre-refactor"
                )

    def test_pinned_compare_mechanisms_gc_cell(self):
        """Same contract through the FTL prepass (WA/GC counters too)."""
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1200)
        grid = compare_mechanisms(w, AGED, mechanisms=("baseline", "pr2ar2"),
                                  seed=1, gc="prepass")
        for mech, want in GOLDEN["compare_gc_prepass"].items():
            got = dataclasses.asdict(grid[mech])
            for field, v in want.items():
                assert got[field] == v, f"{mech}.{field}: GC-cell stats drifted"


class TestRequestTraceValidation:
    def test_valid_trace_passes(self):
        _valid_trace()

    def test_length_mismatch(self):
        with pytest.raises(ValueError, match="equal lengths"):
            _valid_trace(is_read=np.zeros(3, bool))

    def test_negative_arrival(self):
        arr = np.linspace(0.0, 700.0, 8)
        arr[3] = -1.0
        with pytest.raises(ValueError, match="non-negative"):
            _valid_trace(arrival_us=arr)

    def test_nan_arrival(self):
        arr = np.linspace(0.0, 700.0, 8)
        arr[0] = np.nan
        with pytest.raises(ValueError, match="non-finite"):
            _valid_trace(arrival_us=arr)

    def test_zero_pages(self):
        with pytest.raises(ValueError, match="n_pages must be >= 1"):
            _valid_trace(n_pages=np.zeros(8, np.int64))

    def test_float_pages_rejected(self):
        with pytest.raises(ValueError, match="integer dtype"):
            _valid_trace(n_pages=np.full(8, 2.0))

    def test_non_bool_is_read_rejected(self):
        with pytest.raises(ValueError, match="must be bool"):
            _valid_trace(is_read=np.ones(8, np.int64))

    def test_non_array_rejected(self):
        with pytest.raises(ValueError, match="numpy array"):
            _valid_trace(start_page=[0] * 8)

    def test_empty_rejected(self):
        with pytest.raises(ValueError, match="at least one"):
            _valid_trace(
                arrival_us=np.zeros(0), is_read=np.zeros(0, bool),
                n_pages=np.zeros(0, np.int64),
                start_page=np.zeros(0, np.int64),
            )


class TestTransforms:
    def test_dense_remap_bijection_on_touched_pages(self):
        """Acceptance: the remap is a bijection touched -> [0, footprint)
        preserving request order and intra-request page contiguity."""
        t = cached_trace(make_workloads()["usr"], seed=0)
        d = DenseRemap().apply(t)
        before = touched_pages(t)
        after = touched_pages(d)
        # bijection onto the dense range
        np.testing.assert_array_equal(after,
                                      np.arange(before.size, dtype=np.int64))
        # order-preserving page map: start pages map through searchsorted
        np.testing.assert_array_equal(
            d.start_page, np.searchsorted(before, t.start_page))
        # request order/sizes/kinds untouched
        np.testing.assert_array_equal(d.arrival_us, t.arrival_us)
        np.testing.assert_array_equal(d.is_read, t.is_read)
        np.testing.assert_array_equal(d.n_pages, t.n_pages)
        # intra-request contiguity: every request's last page maps to
        # start + n - 1 (the interval stays an interval)
        last_before = t.start_page + t.n_pages - 1
        last_after = np.searchsorted(before, last_before)
        np.testing.assert_array_equal(last_after,
                                      d.start_page + d.n_pages - 1)

    def test_dense_remap_idempotent(self):
        t = cached_trace(make_workloads()["prn"], seed=1)
        d1 = DenseRemap().apply(t)
        d2 = DenseRemap().apply(d1)
        np.testing.assert_array_equal(d1.start_page, d2.start_page)

    def test_time_rescale_preserves_counts_and_read_ratio(self):
        t = cached_trace(make_workloads()["oltp"], seed=0)
        for tf in (TimeRescale(factor=2.0), TimeRescale(target_iops=5000.0)):
            r = tf.apply(t)
            assert len(r) == len(t)
            np.testing.assert_array_equal(r.is_read, t.is_read)
            np.testing.assert_array_equal(r.n_pages, t.n_pages)
        # factor=2 -> gaps halve -> measured IOPS doubles
        fast = TimeRescale(factor=2.0).apply(t)
        assert trace_stats(fast).iops == pytest.approx(
            2.0 * trace_stats(t).iops, rel=1e-9)
        # target_iops hits the target exactly (measured over the span)
        to = TimeRescale(target_iops=5000.0).apply(t)
        assert trace_stats(to).iops == pytest.approx(5000.0, rel=1e-9)

    def test_time_rescale_knob_validation(self):
        with pytest.raises(ValueError, match="exactly one"):
            TimeRescale()
        with pytest.raises(ValueError, match="exactly one"):
            TimeRescale(factor=2.0, target_iops=100.0)

    def test_rw_filter(self):
        t = cached_trace(make_workloads()["prxy"], seed=0)
        r = RWFilter("read").apply(t)
        w = RWFilter("write").apply(t)
        assert r.is_read.all() and not w.is_read.any()
        assert len(r) + len(w) == len(t)

    def test_window_rebases_time(self):
        t = _valid_trace()
        win = Window(start_us=200.0, end_us=600.0).apply(t)
        assert float(win.arrival_us.min()) == 0.0
        assert len(win) == int(((t.arrival_us >= 200.0)
                                & (t.arrival_us < 600.0)).sum())

    def test_truncate(self):
        t = cached_trace(make_workloads()["graph"], seed=0)
        assert len(Truncate(100).apply(t)) == 100
        assert len(Truncate(10 ** 9).apply(t)) == len(t)

    def test_subsample_deterministic_and_order_preserving(self):
        t = cached_trace(make_workloads()["websearch"], seed=0)
        a = Subsample(0.5).apply(t, seed=11)
        b = Subsample(0.5).apply(t, seed=11)
        c = Subsample(0.5).apply(t, seed=12)
        np.testing.assert_array_equal(a.arrival_us, b.arrival_us)
        assert len(a) != len(c) or not np.array_equal(a.arrival_us,
                                                      c.arrival_us)
        assert (np.diff(a.arrival_us) >= 0).all()   # order preserved
        assert 0.4 < len(a) / len(t) < 0.6

    def test_chain_deterministic_under_fixed_seed(self):
        """Acceptance: transform chains replay identically per seed."""
        src = get_source("websearch?sample=0.7&limit=3000")
        t1, t2 = src.trace(5), src.trace(5)
        assert t1 is t2    # cache hit on identical key
        fresh = get_source("websearch?sample=0.7&limit=3000").trace(5)
        np.testing.assert_array_equal(t1.arrival_us, fresh.arrival_us)
        other = src.trace(6)
        assert len(other) != len(t1) or not np.array_equal(
            t1.arrival_us, other.arrival_us)

    def test_empty_selection_raises(self):
        t = _valid_trace()
        with pytest.raises(ValueError, match="zero requests"):
            Window(start_us=10_000.0, end_us=20_000.0).apply(t)


class TestIngest:
    def test_msr_round_trip_stats(self):
        """Acceptance: parse -> stats lands on the excerpt's generation
        parameters (web_0: ~11k IOPS, 90% reads; src1_1: ~9k IOPS, 25%
        reads) within tolerance."""
        st = trace_stats(load_msr_csv(DATA / "web_0.csv.gz"))
        assert st.n_requests == 2600
        assert st.iops == pytest.approx(11000, rel=0.15)
        assert st.read_ratio == pytest.approx(0.90, abs=0.03)
        st2 = trace_stats(load_msr_csv(DATA / "src1_1.csv.gz"))
        assert st2.n_requests == 2600
        assert st2.iops == pytest.approx(9000, rel=0.15)
        assert st2.read_ratio == pytest.approx(0.25, abs=0.03)
        # src1_1 is the hot-span GC excerpt: small footprint, overwrites
        assert st2.footprint_pages < 1100

    def test_gzip_and_plain_files_parse_identically(self, tmp_path):
        plain = tmp_path / "web_0.csv"
        plain.write_bytes(gzip.decompress((DATA / "web_0.csv.gz").read_bytes()))
        a = load_msr_csv(DATA / "web_0.csv.gz")
        b = load_msr_csv(plain)
        np.testing.assert_array_equal(a.arrival_us, b.arrival_us)
        np.testing.assert_array_equal(a.start_page, b.start_page)

    def test_msr_pages_and_timestamps(self, tmp_path):
        p = tmp_path / "mini.csv"
        base = 128_166_372_000_000_000
        p.write_text(
            f"{base},h,0,Read,16384,16384,100\n"          # page 1, 1 page
            f"{base + 10_000},h,0,Write,16000,1000,100\n"  # straddles 0-1
            f"{base + 20_000},h,0,Read,0,65536,100\n"      # pages 0-3
        )
        t = load_msr_csv(p)
        np.testing.assert_array_equal(t.start_page, [1, 0, 0])
        np.testing.assert_array_equal(t.n_pages, [1, 2, 4])
        np.testing.assert_allclose(t.arrival_us, [0.0, 1000.0, 2000.0])
        np.testing.assert_array_equal(t.is_read, [True, False, True])

    def test_msr_filetime_rebased_in_integer_domain(self, tmp_path):
        """FILETIME ticks exceed float64's 2^53 exact range (ulp = 1.6us);
        gaps must come out exact, not quantized to the float grid."""
        p = tmp_path / "prec.csv"
        base = 128_166_372_000_000_065
        p.write_text(
            f"{base},h,0,Read,0,4096,1\n"
            f"{base + 77},h,0,Read,4096,4096,1\n"     # 77 ticks = 7.7 us
            f"{base + 191},h,0,Write,8192,4096,1\n"   # 191 ticks = 19.1 us
        )
        t = load_msr_csv(p)
        np.testing.assert_array_equal(t.arrival_us, [0.0, 7.7, 19.1])

    def test_msr_seconds_timestamps_accepted(self, tmp_path):
        p = tmp_path / "sec.csv"
        p.write_text("0.5,h,0,Read,0,4096,1\n1.5,h,0,Write,4096,4096,1\n")
        t = load_msr_csv(p)
        np.testing.assert_allclose(t.arrival_us, [0.0, 1e6])

    def test_msr_malformed_rows_raise(self, tmp_path):
        bad_fields = tmp_path / "bad1.csv"
        bad_fields.write_text("1,2,3\n")
        with pytest.raises(ValueError, match="7 CSV fields"):
            load_msr_csv(bad_fields)
        bad_type = tmp_path / "bad2.csv"
        bad_type.write_text(
            "128166372000000000,h,0,Read,0,4096,1\n"
            "128166372000010000,h,0,Flush,0,4096,1\n"
        )
        with pytest.raises(ValueError, match="unknown Type"):
            load_msr_csv(bad_type)
        bad_num = tmp_path / "bad3.csv"
        bad_num.write_text("128166372000000000,h,0,Read,xyz,4096,1\n")
        with pytest.raises(ValueError, match="non-numeric"):
            load_msr_csv(bad_num)
        empty = tmp_path / "empty.csv"
        empty.write_text("\n")
        with pytest.raises(ValueError, match="no parsable"):
            load_msr_csv(empty)

    def test_msr_header_skipped_but_malformed_first_row_raises(self, tmp_path):
        """Only a genuinely non-numeric line 1 reads as a header; a
        malformed first *record* fails like any other row."""
        hdr = tmp_path / "hdr.csv"
        hdr.write_text(
            "Timestamp,Hostname,DiskNumber,Type,Offset,Size,ResponseTime\n"
            "128166372000000000,h,0,Read,0,4096,1\n"
        )
        assert len(load_msr_csv(hdr)) == 1
        bad = tmp_path / "bad_first.csv"
        bad.write_text("128166372000000000,h,0,Flush,0,4096,1\n"
                       "128166372000010000,h,0,Read,0,4096,1\n")
        with pytest.raises(ValueError, match="unknown Type"):
            load_msr_csv(bad)

    def test_blktrace_parses_q_events_only(self):
        t = load_blktrace_txt(DATA / "blk_sample.txt")
        assert len(t) == 420                    # C/P/summary lines skipped
        assert 0.5 < float(t.is_read.mean()) < 0.7
        # sectors were 8-aligned 512B units -> 4 KiB aligned bytes
        assert int(t.n_pages.min()) >= 1

    def test_file_source_cache_keyed_by_content(self, tmp_path):
        p = tmp_path / "t.csv"
        p.write_text("128166372000000000,h,0,Read,0,4096,1\n"
                     "128166372000010000,h,0,Write,4096,8192,1\n")
        s = FileSource(path=str(p), fmt="msr")
        t1 = s.trace(0)
        assert s.trace(0) is t1                 # memoized
        # different seeds share the build when no seeded transform exists
        assert s.trace(3) is t1
        import os
        p.write_text("128166372000000000,h,0,Read,0,4096,1\n")
        os.utime(p, ns=(1, 1))                  # force mtime change
        t2 = FileSource(path=str(p), fmt="msr").trace(0)
        assert len(t2) == 1 and len(t1) == 2    # content change re-parses


class TestRegistry:
    def test_synthetic_specs(self):
        assert get_source("websearch").trace(0) is \
            cached_trace(make_workloads()["websearch"], seed=0)
        assert len(get_source("synthetic:oltp?limit=50").trace(0)) == 50

    def test_unknown_names_and_params(self):
        with pytest.raises(KeyError, match="unknown trace source"):
            get_source("nope")
        with pytest.raises(ValueError, match="unknown trace scheme"):
            get_source("ftp:web_0")
        with pytest.raises(ValueError, match="unknown param"):
            get_source("websearch?bogus=1")
        with pytest.raises(ValueError, match="both rescale= and iops="):
            get_source("msr:web_0?rescale=0.5&iops=1000")
        with pytest.raises(ValueError, match="malformed param"):
            get_source("websearch?limit")
        with pytest.raises(FileNotFoundError, match="not found"):
            get_source("msr:no_such_volume")
        with pytest.raises(ValueError, match="dense= must be"):
            get_source("msr:web_0?dense=maybe")   # garbage never coerces
        with pytest.raises(ValueError, match="unknown param"):
            get_source("msr:web_0?action=Z")      # blktrace-only knob
        # boolean spellings resolve, not silently enable
        off = trace_stats(get_source("msr:web_0?dense=False").trace(0))
        assert off.span_pages > off.footprint_pages   # remap disabled

    def test_trace_cache_is_bounded(self, tmp_path):
        """The source-trace cache is LRU-bounded like cached_trace's
        lru_cache(128) — unbounded seeded sweeps cannot grow memory."""
        from repro.flashsim.workloads import Truncate, clear_trace_cache
        from repro.flashsim.workloads.base import (_TRACE_CACHE,
                                                   _TRACE_CACHE_MAX)

        clear_trace_cache()
        src = SyntheticSource(
            dataclasses.replace(make_workloads()["oltp"], n_requests=400))
        for n in range(2, _TRACE_CACHE_MAX + 40):
            src.with_transforms(Truncate(n)).trace(0)
        assert len(_TRACE_CACHE) <= _TRACE_CACHE_MAX
        clear_trace_cache()

    def test_file_spec_dense_by_default(self):
        dense = get_source("msr:web_0").trace(0)
        sparse = get_source("msr:web_0?dense=0").trace(0)
        st_d, st_s = trace_stats(dense), trace_stats(sparse)
        assert st_d.footprint_pages == st_s.footprint_pages
        assert st_d.span_pages == st_d.footprint_pages    # dense
        assert st_s.span_pages > 100 * st_s.footprint_pages  # raw LBAs

    def test_rescale_param(self):
        base = trace_stats(get_source("msr:web_0").trace(0))
        half = trace_stats(get_source("msr:web_0?rescale=0.5").trace(0))
        assert half.iops == pytest.approx(base.iops * 0.5, rel=1e-6)

    def test_registered_source(self):
        register_source("pinned-oltp",
                        SyntheticSource(make_workloads()["oltp"]))
        t = get_source("pinned-oltp?limit=20").trace(0)
        assert len(t) == 20

    def test_file_parsed_once_across_seeds(self, monkeypatch):
        """The raw file build is seed-independent: deterministic chains
        serve every seed from one trace object, and seeded chains
        re-run only the transforms — the CSV parse happens once."""
        import repro.flashsim.workloads.ingest as ing
        from repro.flashsim.workloads import clear_trace_cache

        clear_trace_cache()
        calls = []
        orig = ing.load_msr_csv
        monkeypatch.setattr(ing, "load_msr_csv",
                            lambda *a, **k: calls.append(1) or orig(*a, **k))
        det = get_source("msr:web_0")              # DenseRemap only
        assert all(det.trace(s) is det.trace(0) for s in range(4))
        sub = get_source("msr:web_0?sample=0.9")   # seeded chain
        a, b = sub.trace(0), sub.trace(1)
        assert len(a) != len(b) or not np.array_equal(a.arrival_us,
                                                      b.arrival_us)
        assert len(calls) == 1, f"{len(calls)} parses for one file"


class TestGeneratorShapeValidation:
    """Acceptance: trace_stats recovers each profile's Workload spec.

    Documented tolerances (20k-request traces, fixed seed 0): IOPS within
    10%, read ratio within 0.02 absolute, mean request size within 5%,
    MMPP burstiness within max(0.25, 15% of spec) — the SCV inversion is
    a moment estimator, looser than the direct rate/ratio measurements.
    """

    @pytest.mark.parametrize("w", PROFILES + GC_PROFILES,
                             ids=lambda w: w.name)
    def test_profile_stats_match_spec(self, w):
        st = trace_stats(cached_trace(w, seed=0))
        assert st.iops == pytest.approx(w.iops, rel=0.10)
        assert st.read_ratio == pytest.approx(w.read_ratio, abs=0.02)
        assert st.mean_pages == pytest.approx(w.mean_pages, rel=0.05)
        tol = max(0.25, 0.15 * w.burstiness)
        assert abs(st.mmpp_burstiness - w.burstiness) <= tol, (
            f"{w.name}: measured burstiness {st.mmpp_burstiness:.2f} "
            f"outside {w.burstiness} +- {tol:.2f}"
        )
        assert st.footprint_pages <= w.span_pages


class TestRunAPIIntegration:
    def test_spec_string_equals_workload_object(self):
        """The two spellings of a synthetic profile never diverge: a bare
        spec string with n_requests takes the same regenerate path as the
        Workload-object call — bit-identical SimStats."""
        w = make_workloads()["websearch"]
        a = simulate(w, AGED, "pr2ar2", seed=2, n_requests=300)
        b = simulate("websearch", AGED, "pr2ar2", seed=2, n_requests=300)
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
        # a registered source of the pre-shortened profile agrees too
        w300 = dataclasses.replace(w, n_requests=300)
        register_source("websearch-300", SyntheticSource(w300))
        c = simulate("websearch-300", AGED, "pr2ar2", seed=2)
        assert dataclasses.asdict(a) == dataclasses.asdict(c)

    def test_resolve_trace_forms(self):
        w = make_workloads()["oltp"]
        assert resolve_trace(w, seed=1) is cached_trace(w, seed=1)
        # bare profile string + n_requests == the regenerate path
        w64 = dataclasses.replace(w, n_requests=64)
        assert resolve_trace("oltp", seed=1, n_requests=64) is \
            cached_trace(w64, seed=1)
        src = SyntheticSource(w)
        assert resolve_trace(src, seed=1) is cached_trace(w, seed=1)
        # a transformed synthetic source truncates instead (chain applies)
        t = resolve_trace("oltp?rw=read", seed=1, n_requests=64)
        assert len(t) == 64 and t.is_read.all()
        with pytest.raises(TypeError, match="trace spec"):
            resolve_trace(123)

    def test_real_trace_replay_end_to_end(self):
        """Acceptance: compare_mechanisms over both checked-in MSR
        excerpts (dense remap + FTL auto-sizing) yields finite stats for
        baseline / PR2 / AR2."""
        for spec in ("msr:web_0", "msr:src1_1"):
            grid = compare_mechanisms(
                spec, AGED, mechanisms=("baseline", "pr2", "ar2", "pr2ar2"),
                seed=0, gc="prepass",
            )
            for mech, st in grid.items():
                for f in ("mean_us", "p99_us", "read_p99_us", "wa"):
                    v = float(getattr(st, f))
                    assert np.isfinite(v) and v >= 0, (spec, mech, f, v)
            assert grid["baseline"].wa > 1.0          # the FTL engaged
            assert grid["pr2ar2"].mean_us < grid["baseline"].mean_us

    def test_ftl_auto_sizes_from_dense_footprint_not_span(self):
        """Acceptance: auto-OP sizing tracks the remapped dense footprint.
        web_0's raw span is ~1900x its footprint; sizing must stay
        footprint-proportional for both the raw and the remapped trace —
        never span-proportional."""
        cfg = SSDConfig(gc=GCConfig(enabled=True))
        sparse = get_source("msr:web_0?dense=0").trace(0)
        dense = get_source("msr:web_0").trace(0)
        st_sp = build_ftl_schedule(sparse, cfg).stats
        st_dn = build_ftl_schedule(dense, cfg).stats
        assert st_sp.footprint_pages == st_dn.footprint_pages
        span = trace_stats(sparse).span_pages
        span_blocks_per_die = span / (cfg.n_dies * st_dn.pages_per_block)
        for st in (st_sp, st_dn):
            # footprint-proportional (small constant over the per-die
            # demand + OP + frontier floor), orders below span scale
            assert st.blocks_per_die < 0.01 * span_blocks_per_die
        # once remapped, striping is balanced: capacity within a small
        # factor of the ideal footprint/(1-OP) packing
        ideal = st_dn.footprint_pages / (1 - cfg.gc.op_ratio)
        physical = cfg.n_dies * st_dn.blocks_per_die * st_dn.pages_per_block
        assert physical < 2.0 * ideal

    def test_simulate_accepts_file_source_with_overrides(self):
        """n_requests slots into the canonical chain position (before
        dense/sample), so it behaves exactly like ?limit=N."""
        st = simulate("msr:src1_1?sample=0.8", AGED, "baseline", seed=1,
                      n_requests=1000)
        ref = simulate("msr:src1_1?limit=1000&sample=0.8", AGED, "baseline",
                       seed=1)
        assert st.n_requests == ref.n_requests
        assert 700 <= st.n_requests <= 900     # ~0.8 * 1000 kept
        assert np.isfinite(st.mean_us)
        # the ?limit=N equivalence holds for every transform mix
        for spec in ("websearch?sample=0.5", "msr:web_0?dense=0&iops=5000",
                     "msr:web_0?rw=read"):
            kw = resolve_trace(spec, seed=0, n_requests=500)
            lim = get_source(f"{spec}&limit=500").trace(0)
            np.testing.assert_array_equal(kw.arrival_us, lim.arrival_us)
            np.testing.assert_array_equal(kw.start_page, lim.start_page)

    def test_n_requests_truncates_before_dense_remap(self):
        """The run-API n_requests knob slots its Truncate before the
        file-scheme's default DenseRemap, so it matches ?limit=N and the
        dense [0, footprint) guarantee survives truncation."""
        via_kw = resolve_trace("msr:web_0", seed=0, n_requests=1500)
        via_limit = get_source("msr:web_0?limit=1500").trace(0)
        np.testing.assert_array_equal(via_kw.start_page,
                                      via_limit.start_page)
        st = trace_stats(via_kw)
        assert st.span_pages == st.footprint_pages   # still dense
