"""Serve a small model with batched requests: retry-aware KV vs baseline.

Compares RetryPolicy("baseline") (every KV read from full-precision
backing) against RetryPolicy("pr2ar2") (int8 fast tier with margin-aware
retry — the AR² adaptation) on the same prompts, reporting:

  * greedy outputs (identical under a sane margin tolerance tau);
  * fast-tier hit rate and HBM bytes saved;
  * a tau sweep showing the margin/traffic trade-off (the serving twin of
    the paper's tR-scale characterization).

Usage: PYTHONPATH=src python examples/serve_retry.py [--arch llama3.2-3b]
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.core.retry import RetryPolicy
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab, size=rng.integers(3, 9)).astype(np.int32)
        for _ in range(args.batch)
    ]

    print(f"arch={cfg.name} batch={args.batch} max_new={args.max_new}")
    base_eng = ServeEngine(cfg, policy=RetryPolicy("baseline"), seed=0)
    base_out, base_stats = base_eng.generate(prompts, max_new_tokens=args.max_new)
    print(f"  baseline : {base_stats.summary()}")

    for tau in (0.01, 0.05, 0.2):
        eng = ServeEngine(
            cfg, params=base_eng.params, policy=RetryPolicy("pr2ar2"),
            tau=tau, seed=0,
        )
        out, stats = eng.generate(prompts, max_new_tokens=args.max_new)
        same = np.array_equal(out, base_out)
        print(f"  pr2ar2 tau={tau:4.2f}: {stats.summary()} outputs_match={same}")

    print("sample generation (request 0):", base_out[0].tolist())


if __name__ == "__main__":
    main()
