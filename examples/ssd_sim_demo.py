"""SSD simulator demo: mechanisms x workloads x operating conditions.

A compact tour of the flashsim reproduction: for each mechanism, simulate
two workloads at two conditions and print mean/p99 response times plus
the attempt counts the 160-chip characterization transplanted in.

Each (workload, condition) cell runs through ``compare_mechanisms``, so
the trace is generated once and shared by every mechanism (all mechanisms
see the same arrivals), and the per-page schedule is expanded once.  A
``simulate_batch`` sweep shows the throughput API for (mechanism x
condition x seed) grids, and the closing sections turn on the
page-mapping FTL (``SSDConfig.gc``) to show read-retry behind GC-induced
die contention — write amplification, the host-read tail inflation, and
how much of it PR²+AR² claws back — then sweep the die-queue scheduler
(``scheduler="fcfs" / "host_prio" / "preempt"``) under online
(completion-time-triggered) GC to show firmware read-prioritization and
GC suspension collapsing the inflation at equal write amplification.
The final section replays the checked-in MSR-format excerpts through
the ingestion -> dense-remap -> FTL path — the paper's actual
evaluation scenario (real block traces) end to end.

Usage: PYTHONPATH=src python examples/ssd_sim_demo.py [--n 4000]
"""

from __future__ import annotations

import argparse

from repro.flashsim.config import GCConfig, OperatingCondition, SSDConfig
from repro.flashsim.runtime import sweep_to_json
from repro.flashsim.ssd import compare_mechanisms, simulate, simulate_batch
from repro.flashsim.workloads import get_source, make_workloads, trace_stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    args = ap.parse_args()

    workloads = make_workloads()
    conditions = (
        OperatingCondition(90.0, 0.0),      # modest: 3-month retention
        OperatingCondition(365.0, 1000.0),  # aged
    )
    mechanisms = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")

    for cond in conditions:
        print(f"== condition {cond.label()} ==")
        for wname in ("websearch", "oltp"):
            w = workloads[wname]
            print(f"  [{wname}] read_ratio={w.read_ratio}")
            stats = compare_mechanisms(
                w, cond, mechanisms=mechanisms, n_requests=args.n
            )
            base = stats["baseline"].mean_us
            for mech in mechanisms:
                st = stats[mech]
                delta = f"{100 * (1 - st.mean_us / base):+5.1f}%"
                print(f"    {mech:12s} {st.as_row()}  vs_base={delta}")

    # Sweep API: every (mechanism, condition, seed) cell of one workload,
    # reusing the per-seed trace/expansion and cached characterization.
    print("== simulate_batch: pr2ar2 across conditions x 2 seeds ==")
    grid = simulate_batch(
        workloads["websearch"],
        conditions,
        mechanisms=("baseline", "pr2ar2"),
        seeds=(0, 1),
        n_requests=args.n,
    )
    for cond in conditions:
        for seed in (0, 1):
            red = 1.0 - (
                grid[("pr2ar2", cond, seed)].mean_us
                / grid[("baseline", cond, seed)].mean_us
            )
            print(
                f"  {cond.label():>12s} seed={seed}: "
                f"pr2ar2 vs baseline -{100 * red:5.1f}%"
            )

    # FTL/GC: sustained small-span overwrites fill the over-provisioned
    # capacity; GC copy-back traffic then contends with host reads on the
    # die queues.  The same trace runs with GC off (in-place programs) and
    # on, for the worst (baseline) and best (pr2ar2) mechanisms.
    print("== FTL/GC: write-heavy 'prn' under aged condition ==")
    aged = conditions[1]
    w = workloads["prn"]
    cfg_gc = SSDConfig(gc=GCConfig(enabled=True))
    # GC intensity is non-monotonic in trace length (physical capacity
    # auto-sizes with the footprint, which grows with n), with a
    # near-dead zone around ~2k requests for this profile; floor the
    # cell size where the collector reliably churns.
    n_gc = max(args.n, 4000)
    for mech in ("baseline", "pr2ar2"):
        off = simulate(w, aged, mech, n_requests=n_gc)
        on = simulate(w, aged, mech, n_requests=n_gc, cfg=cfg_gc)
        print(
            f"  {mech:9s} GC off: read_p99={off.read_p99_us:9.0f}us | "
            f"GC on: read_p99={on.read_p99_us:9.0f}us "
            f"(x{on.read_p99_us / off.read_p99_us:5.1f})  "
            f"WA={on.wa:.2f} gc_inv={on.gc_invocations} "
            f"erased={on.blocks_erased}"
        )

    # Scheduler layer: the same write-heavy trace under online GC
    # (completion-time watermark triggering) across the three die-queue
    # policies.  host_prio lets host reads jump the GC backlog; preempt
    # additionally suspends in-flight GC ops at read arrival — the read
    # tail collapses while WA stays put (the scheduler reorders service,
    # not the overwrite structure).
    print("== scheduler sweep: online GC, write-heavy 'prn' ==")
    off = simulate(w, aged, "baseline", n_requests=n_gc)
    for sched in ("fcfs", "host_prio", "preempt"):
        on = simulate(w, aged, "baseline", n_requests=n_gc,
                      scheduler=sched, gc="online")
        print(
            f"  {sched:9s} read_p99={on.read_p99_us:9.0f}us "
            f"(x{on.read_p99_us / off.read_p99_us:6.1f} vs GC off)  "
            f"WA={on.wa:.2f} stalls={on.write_stalls} "
            f"suspensions={on.gc_suspensions}"
        )

    # Real-trace replay: the checked-in MSR-format excerpts resolve by
    # spec string through the workload registry; raw sparse LBAs are
    # densely remapped (file-scheme default) so the FTL auto-sizes from
    # the footprint, and each excerpt runs every mechanism over one
    # shared trace with prepass GC.
    print("== real-trace replay: MSR-format excerpts (tests/data) ==")
    for spec in ("msr:web_0", "msr:src1_1"):
        st = trace_stats(get_source(spec).trace(0))
        print(f"  [{spec}] {st.as_row()}")
        grid = compare_mechanisms(spec, aged,
                                  mechanisms=("baseline", "pr2", "ar2",
                                              "pr2ar2"),
                                  gc="prepass")
        base = grid["baseline"]
        for mech, s in grid.items():
            delta = f"{100 * (1 - s.mean_us / base.mean_us):+5.1f}%"
            print(f"    {mech:9s} {s.as_row()}  vs_base={delta}")

    # Sharded runtime: per-channel shard loops are bit-identical to the
    # monolithic engine (shard=True), and the parallel sweep executor
    # returns byte-identical grids for any worker count (workers=N).
    print("== sharded runtime: shard equivalence + parallel sweep ==")
    mono = simulate(w, aged, "pr2ar2", n_requests=n_gc, gc="online")
    shrd = simulate(w, aged, "pr2ar2", n_requests=n_gc, gc="online",
                    shard=True)
    print(f"  shard=True bit-identical: {mono == shrd}")
    blobs = {
        wk: sweep_to_json(simulate_batch(
            w, (aged,), mechanisms=("baseline", "pr2ar2"), seeds=(0, 1),
            n_requests=1000, workers=wk,
        ))
        for wk in (1, 2)
    }
    print(f"  workers 1 vs 2 byte-identical: {blobs[1] == blobs[2]}")


if __name__ == "__main__":
    main()
