"""SSD simulator demo: mechanisms x workloads x operating conditions.

A compact tour of the flashsim reproduction: for each mechanism, simulate
two workloads at two conditions and print mean/p99 response times plus
the attempt counts the 160-chip characterization transplanted in.

Usage: PYTHONPATH=src python examples/ssd_sim_demo.py [--n 4000]
"""

from __future__ import annotations

import argparse

from repro.flashsim.config import OperatingCondition
from repro.flashsim.ssd import simulate
from repro.flashsim.workloads import make_workloads


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    args = ap.parse_args()

    workloads = make_workloads()
    conditions = (
        OperatingCondition(90.0, 0.0),      # modest: 3-month retention
        OperatingCondition(365.0, 1000.0),  # aged
    )
    mechanisms = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")

    for cond in conditions:
        print(f"== condition {cond.label()} ==")
        for wname in ("websearch", "oltp"):
            w = workloads[wname]
            print(f"  [{wname}] read_ratio={w.read_ratio}")
            base = None
            for mech in mechanisms:
                st = simulate(w, cond, mech, n_requests=args.n)
                if mech == "baseline":
                    base = st.mean_us
                delta = f"{100 * (1 - st.mean_us / base):+5.1f}%" if base else ""
                print(f"    {mech:12s} {st.as_row()}  vs_base={delta}")


if __name__ == "__main__":
    main()
