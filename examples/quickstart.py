"""Quickstart: the paper's mechanisms in five minutes, end to end.

  1. characterize a (retention, P/E) condition on the simulated 160-chip
     population -> retry steps, ECC margin, safe tR scale;
  2. closed-form read latency: BASELINE vs PR² vs AR² vs PR²+AR²;
  3. one SSD simulation cell (websearch workload, aged condition);
  4. one tiny LM train step + one serve step through the framework, with
     the retry-aware data/KV paths.

Usage: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import characterize as CH
from repro.core import timing as T
from repro.flashsim.config import OperatingCondition
from repro.flashsim.ssd import simulate
from repro.flashsim.workloads import PROFILES


def main():
    print("== 1. characterization (160 simulated chips) ==")
    for cond in ((90.0, 0.0), (365.0, 1500.0)):
        s = CH.characterize_condition(*cond)
        print(
            f"  {cond[0]:5.0f}d/{cond[1]:6.0f}PE: retry steps "
            f"mean={s.mean_retry_steps:5.2f} p99={s.p99_retry_steps:4.1f} | "
            f"ECC margin={s.mean_margin_final:.3f} | safe tR x{s.safe_tr_scale}"
        )

    print("== 2. closed-form read latency (csb page, k attempts) ==")
    for a in (1, 3, 6):
        row = {
            m: float(T.read_latency(a, m, tr_scale=0.75))
            for m in ("baseline", "pr2", "ar2", "pr2ar2")
        }
        print(f"  attempts={a}: " + "  ".join(f"{m}={v:6.1f}us" for m, v in row.items()))

    print("== 3. SSD simulation (websearch @ 1yr/1K PE, 3000 requests) ==")
    w = PROFILES[0]
    cond = OperatingCondition(365.0, 1000.0)
    for mech in ("baseline", "pr2ar2", "sota+pr2ar2"):
        st = simulate(w, cond, mech, n_requests=3000)
        print(f"  {mech:12s} {st.as_row()}")

    print("== 4. tiny LM: one train step + serve through the framework ==")
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import reduced_config
    from repro.core.retry import RetryPolicy
    from repro.models.api import build_model
    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
    from repro.serving import ServeEngine

    cfg = reduced_config(get_config("llama3.2-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, AdamWConfig())
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab),
    }
    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    params, opt, _ = adamw_update(grads, opt, params, AdamWConfig())
    print(f"  train step: loss={float(loss):.3f} (vocab={cfg.vocab})")

    eng = ServeEngine(cfg, params=params, policy=RetryPolicy("pr2ar2"), tau=0.2)
    gen, st = eng.generate([np.array([5, 9, 11], np.int32)], max_new_tokens=6)
    print(f"  serve: tokens={gen[0].tolist()} | {st.summary()}")


if __name__ == "__main__":
    main()
