"""End-to-end training driver: ~100M-param LM, few hundred steps, with the
paper's retry-aware substrate under it.

Pieces exercised:
  * synthetic corpus -> FlashTierReader (batches charged simulated SSD read
    latency under a RetryPolicy) -> PrefetchPipeline (double-buffered);
  * AdamW + cosine schedule + global-norm clip;
  * CheckpointManager: erasure-coded saves every --save-every steps,
    pipelined-retry restore, --resume restarts from the latest valid
    checkpoint (kill the process mid-run to test);
  * optional int8 gradient compression with error feedback (--compress).

Usage:
  PYTHONPATH=src python examples/train_lm.py --steps 300
  PYTHONPATH=src python examples/train_lm.py --steps 40 --size tiny  # quick
  PYTHONPATH=src python examples/train_lm.py --resume               # restart
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.core.retry import RetryPolicy
from repro.data import CorpusConfig, FlashTierReader, PrefetchPipeline, SyntheticCorpus
from repro.distributed.compress import compress_grads, init_error_feedback
from repro.flashsim.config import OperatingCondition
from repro.models.api import build_model
from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    cosine_schedule,
    init_opt_state,
)

SIZES = {
    # ~100M params: 12 x (d=576, ff=1536) + 32k vocab ~= 86M
    "100m": ModelConfig(
        name="repro-lm-100m", n_layers=12, d_model=576, n_heads=9,
        n_kv_heads=3, d_ff=1536, vocab=32768, head_dim=64,
    ),
    "10m": ModelConfig(
        name="repro-lm-10m", n_layers=6, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=688, vocab=8192, head_dim=64,
    ),
    "tiny": ModelConfig(
        name="repro-lm-tiny", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, head_dim=16,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="100m", choices=sorted(SIZES))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", action="store_true",
                    help="int8 gradient compression with error feedback")
    ap.add_argument("--retry-mechanism", default="pr2ar2")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = SIZES[args.size]
    model = build_model(cfg)
    n_params = cfg.n_params()
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params "
          f"(batch={args.batch} seq={args.seq})")

    opt_cfg = AdamWConfig(lr=args.lr)
    total_steps = args.steps
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, opt_cfg)
    ef = init_error_feedback(params) if args.compress else None
    start_step = 0

    mgr = CheckpointManager(
        args.ckpt_dir, keep=2, save_every=args.save_every, parity_group=4
    )
    if args.resume:
        step0, state, rstats = mgr.restore_latest(
            {"params": params, "opt": opt}
        )
        if step0 is not None:
            params = jax.tree.map(jnp.asarray, state["params"])
            opt = jax.tree.map(jnp.asarray, state["opt"])
            start_step = step0
            print(
                f"resumed from step {step0} "
                f"(restore {rstats.wall_s * 1e3:.0f}ms, "
                f"{rstats.n_reconstructed} shards reconstructed, "
                f"pipelined={rstats.pipelined})"
            )
        else:
            print("no checkpoint found; cold start")

    corpus = SyntheticCorpus(
        CorpusConfig(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    )
    reader = FlashTierReader(
        corpus,
        RetryPolicy(args.retry_mechanism),
        OperatingCondition(retention_days=365.0, pec=1000.0),
    )

    @jax.jit
    def train_step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        lr_scale = cosine_schedule(opt["step"], total_steps, warmup=20)
        new_params, new_opt, metrics = adamw_update(
            grads, opt, params, opt_cfg, lr_scale=lr_scale
        )
        metrics["loss"] = loss
        return new_params, new_opt, grads, metrics

    pipe = PrefetchPipeline(
        lambda i: reader.read(i),
        n_batches=args.steps - start_step,
        start_index=start_step,
    )

    losses = []
    t_run = time.perf_counter()
    for i, batch in pipe:
        t0 = time.perf_counter()
        params, opt, grads, metrics = train_step(params, opt, batch)
        if args.compress:
            # compression demo: quantize the *next* step's wire format
            _, ef = compress_grads(grads, ef)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (i + 1) % args.log_every == 0 or i == start_step:
            dt = time.perf_counter() - t0
            print(
                f"step {i + 1:4d} loss {loss:7.4f} "
                f"gnorm {float(metrics['grad_norm']):8.3f} "
                f"{dt:6.2f}s/step stall {pipe.stall_s:5.1f}s "
                f"flash-read(sim) {reader.stats.mean_batch_us:7.0f}us/batch",
                flush=True,
            )
        if mgr.should_save(i + 1):
            path = mgr.save(i + 1, {"params": params, "opt": opt})
            print(f"  checkpoint -> {path}", flush=True)

    wall = time.perf_counter() - t_run
    k = max(len(losses) // 10, 1)
    print(
        f"done: {len(losses)} steps in {wall:.0f}s | "
        f"loss {np.mean(losses[:k]):.3f} -> {np.mean(losses[-k:]):.3f} | "
        f"input stall {pipe.stall_s:.1f}s | "
        f"simulated flash read {reader.stats.sim_read_us / 1e6:.2f}s "
        f"({args.retry_mechanism})"
    )
    assert np.mean(losses[-k:]) < np.mean(losses[:k]), "loss did not improve"


if __name__ == "__main__":
    main()
