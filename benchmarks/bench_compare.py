"""Compare the deterministic payload of two BENCH_sim.json files.

The sweep runtime's contract is that worker count changes *when* cells
run, never *what* they compute: a ``microbench_sim --workers 2`` run
must produce exactly the per-cell numbers of a ``--workers 1`` run.
This tool strips the timing-derived fields (wall clocks, events/sec,
speedups, host fingerprint) from both files and diffs the rest — the CI
bench-smoke lane runs it to block any divergence.

Usage: python -m benchmarks.bench_compare A.json B.json

Exit status 0 when the deterministic payloads are byte-identical after
canonicalization; 1 with a diff summary otherwise.  If either file's
``summary.parallel`` block is present, its ``cells_equal`` flag (the
in-run workers=1 vs workers=N equality check) must be true as well —
unless the block was *gated* on a single-core host, in which case it
carries ``skipped`` + ``skipped_reason`` instead of measurements and
passes (the payload diff still covers worker-count determinism).
"""

from __future__ import annotations

import argparse
import json
import re
import sys

#: Keys whose values are timing-derived (machine/run-dependent) and
#: therefore excluded from the determinism contract.  Everything else —
#: cell statistics, event counts, CIs, deterministic acceptance flags —
#: must match.  Anchored prefixes, NOT substrings: deterministic
#: payloads like ``sched_cells[*].host_prio`` and
#: ``inflation_cut_host_prio`` must stay inside the comparison.
#: Wall-clock speedups embedded under non-``speedup`` prefixes
#: (``batched_speedup_*``, ``sweep_speedup``) and the acceptance flags
#: thresholded on those speedups are excluded too — they legitimately
#: vary run-to-run on a noisy host.
_TIMING_KEY = re.compile(
    r"^(wall|speedup|events_per_sec|rel_throughput|host_factor"
    r"|characterization_warm|parallel$"
    r"|batched_speedup|sweep_speedup|small_cell_sweep_speedup"
    r"|acceptance_8ch_speedup_ok$|acceptance_8ch_host_prio_ok$"
    r"|acceptance_small_cell_ok$|acceptance_fused_sweep_ok$)"
)

#: Top-level sections that are wholly machine-dependent.
_TIMING_SECTIONS = ("host",)


def strip_timing(node):
    """Recursively drop timing-derived dict keys (see _TIMING_KEY)."""
    if isinstance(node, dict):
        return {
            k: strip_timing(v)
            for k, v in node.items()
            if not _TIMING_KEY.search(k)
        }
    if isinstance(node, list):
        return [strip_timing(v) for v in node]
    return node


def deterministic_payload(doc: dict) -> dict:
    out = {k: v for k, v in doc.items() if k not in _TIMING_SECTIONS}
    return strip_timing(out)


def _diff_paths(a, b, path="$", out=None, limit=20):
    """Collect up to ``limit`` paths where two payloads differ."""
    if out is None:
        out = []
    if len(out) >= limit:
        return out
    if type(a) is not type(b):
        out.append(f"{path}: type {type(a).__name__} != {type(b).__name__}")
    elif isinstance(a, dict):
        for k in sorted(set(a) | set(b)):
            if k not in a:
                out.append(f"{path}.{k}: only in B")
            elif k not in b:
                out.append(f"{path}.{k}: only in A")
            else:
                _diff_paths(a[k], b[k], f"{path}.{k}", out, limit)
    elif isinstance(a, list):
        if len(a) != len(b):
            out.append(f"{path}: length {len(a)} != {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            _diff_paths(x, y, f"{path}[{i}]", out, limit)
    elif a != b:
        out.append(f"{path}: {a!r} != {b!r}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="assert two BENCH_sim.json runs agree on every "
                    "deterministic (non-timing) field"
    )
    ap.add_argument("file_a")
    ap.add_argument("file_b")
    args = ap.parse_args(argv)

    with open(args.file_a) as f:
        doc_a = json.load(f)
    with open(args.file_b) as f:
        doc_b = json.load(f)

    ok = True
    for name, doc in ((args.file_a, doc_a), (args.file_b, doc_b)):
        par = doc.get("summary", {}).get("parallel")
        if par is None:
            continue
        if par.get("skipped"):
            if not par.get("skipped_reason"):
                print(f"FAIL: {name} summary.parallel is skipped but "
                      f"carries no skipped_reason")
                ok = False
        elif not par.get("cells_equal", False):
            print(f"FAIL: {name} summary.parallel.cells_equal is false "
                  f"(in-run workers=1 vs workers=N results diverged)")
            ok = False

    pa = deterministic_payload(doc_a)
    pb = deterministic_payload(doc_b)
    if pa != pb:
        print(f"FAIL: deterministic payloads differ between "
              f"{args.file_a} and {args.file_b}:")
        for line in _diff_paths(pa, pb):
            print(f"  {line}")
        ok = False

    if ok:
        print(f"OK: deterministic payloads identical "
              f"({args.file_a} vs {args.file_b})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
