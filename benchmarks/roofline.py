"""Roofline analysis from the multi-pod dry-run artifacts.

For every (arch x shape) cell on the single-pod production mesh we derive
the three roofline terms from the compiled module (TPU v5e-class constants
from the task spec):

  compute_s    = HLO_FLOPs_per_device / 197e12
  memory_s     = HLO_bytes_per_device / 819e9
  collective_s = ring-traffic bytes (parsed from the partitioned HLO with
                 per-op replica-group multipliers, see launch/dryrun.py)
                 / 50e9 (one ICI link — conservative single-link basis)

plus MODEL_FLOPS (6*N*D train / 2*N*D prefill / 2*N*B decode, N = active
params for MoE), the useful-compute ratio MODEL_FLOPS/HLO_FLOPs (catches
remat/redundancy waste), the dominant term, the estimated MFU at the
roofline bound, and a one-line lever for the dominant term.

Writes results/roofline.md (the EXPERIMENTS.md table) and prints CSV.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops_global(rec: dict) -> float:
    """Useful model FLOPs for the whole step (task-spec convention)."""
    kind = rec["shape_cfg"]["kind"]
    n = rec["model"]["n_active_params"]
    batch = rec["shape_cfg"]["global_batch"]
    seq = rec["shape_cfg"]["seq_len"]
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch  # decode: one token per sequence


LEVERS = {
    "compute": (
        "cut recompute (remat ratio) and raise MXU occupancy: wider scanned "
        "blocks, fused attention kernel, bf16 accumulation where safe"
    ),
    "memory": (
        "cut HBM bytes: bf16/fp8 weights+activations, fuse elementwise "
        "chains, avoid transposed layouts between sharded ops"
    ),
    "collective": (
        "reshard to shrink all-gather volume (2-D FSDPxTP balance), overlap "
        "gathers with per-unit compute, int8-compress gradient reductions"
    ),
}


def analyze(rec: dict) -> dict:
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_accessed_per_device"] / HBM_BW
    coll = rec["collectives"]
    traffic = sum(coll.get("traffic", coll["bytes"]).values())
    collective_s = traffic / LINK_BW
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s
    }
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mf = model_flops_global(rec) / rec["n_devices"]
    useful_ratio = mf / rec["flops_per_device"] if rec["flops_per_device"] else 0.0
    mfu_bound = (mf / PEAK_FLOPS) / bound_s if bound_s else 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "bound_s": bound_s,
        "model_flops_per_dev": mf,
        "useful_ratio": useful_ratio,
        "mfu_bound": mfu_bound,
        "lever": LEVERS[dominant],
    }


def load_cells(mesh: str = "single"):
    if not RESULTS_DIR.exists():
        raise FileNotFoundError(f"{RESULTS_DIR} missing - run the dry-run first")
    cells = []
    for p in sorted(RESULTS_DIR.glob(f"*__{mesh}.json")):
        rec = json.loads(p.read_text())
        cells.append(rec)
    if not cells:
        raise FileNotFoundError(f"no *__{mesh}.json under {RESULTS_DIR}")
    return cells


def build_table(mesh: str = "single"):
    rows, skips = [], []
    for rec in load_cells(mesh):
        if rec.get("status") == "skipped":
            skips.append(rec)
            continue
        rows.append(analyze(rec))
    return rows, skips


def write_markdown(rows, skips, path: Path, mesh: str):
    lines = [
        f"### Roofline — {mesh}-pod mesh (per device; v5e constants: "
        "197 TF/s bf16, 819 GB/s HBM, 50 GB/s/link)",
        "",
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | "
        "dominant | useful/HLO | MFU@bound | lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {1e3 * r['compute_s']:.2f} | "
            f"{1e3 * r['memory_s']:.2f} | {1e3 * r['collective_s']:.2f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{100 * r['mfu_bound']:.1f}% | {r['lever'].split(':')[0]} |"
        )
    for s in skips:
        lines.append(
            f"| {s['arch']} | {s['shape']} | — | — | — | N/A | — | — | "
            f"skipped: {s['reason'][:60]}… |"
        )
    path.write_text("\n".join(lines) + "\n")
    return path


def csv_rows(mesh: str = "single"):
    rows, skips = build_table(mesh)
    out = []
    for r in rows:
        out.append(
            (
                f"roofline/{r['arch']}__{r['shape']}",
                r["bound_s"] * 1e6,
                f"compute={1e3 * r['compute_s']:.2f}ms;"
                f"memory={1e3 * r['memory_s']:.2f}ms;"
                f"collective={1e3 * r['collective_s']:.2f}ms;"
                f"dominant={r['dominant']};useful={r['useful_ratio']:.2f};"
                f"mfu_bound={100 * r['mfu_bound']:.1f}%",
            )
        )
    for s in skips:
        out.append((f"roofline/{s['arch']}__{s['shape']}", 0.0, "skipped"))
    md = write_markdown(
        rows, skips, RESULTS_DIR.parent / f"roofline_{mesh}.md", mesh
    )
    out.append((f"roofline/markdown", 0.0, str(md)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()
    rows, skips = build_table(args.mesh)
    print(
        f"{'arch':26s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
        f"{'collect':>9s}  dominant   useful  MFU@bound"
    )
    for r in rows:
        print(
            f"{r['arch']:26s} {r['shape']:12s} {1e3 * r['compute_s']:8.2f}m "
            f"{1e3 * r['memory_s']:8.2f}m {1e3 * r['collective_s']:8.2f}m  "
            f"{r['dominant']:10s} {r['useful_ratio']:5.2f}  "
            f"{100 * r['mfu_bound']:5.1f}%"
        )
    for s in skips:
        print(f"{s['arch']:26s} {s['shape']:12s} {'skipped':>9s}")
    md = write_markdown(rows, skips, RESULTS_DIR.parent / f"roofline_{args.mesh}.md", args.mesh)
    print(f"wrote {md}")


if __name__ == "__main__":
    main()
