"""Benchmark aggregator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:

  * Observation 1 — retry-step characterization (paper §3 / abstract 4.5);
  * Observation 2 — final-step ECC-capability margin;
  * Observation 3 — safe tR reduction table (the AR² table);
  * §5 headline  — e2e response time, six workloads (vs baseline and SOTA);
  * closed-form  — PR² per-step reduction (28.5%) and latency curves;
  * roofline     — three-term roofline per (arch x shape) from the dry-run
                   artifacts, when results/dryrun is populated.

Usage: PYTHONPATH=src python -m benchmarks.run [--skip-e2e] [--n 8000]
           [--engine {array,reference}]

Flags:
  --skip-e2e   skip the discrete-event simulation table (the slowest
               section; everything else is closed-form or cached).
  --n N        requests per e2e simulation cell (default 8000).
  --engine E   DES engine for the e2e section: "array" (default, the
               integer-opcode event core) or "reference" (the retired
               seed closure engine, kept for validation/speedup runs).

Related stand-alone benchmarks (not aggregated here):
  python -m benchmarks.microbench_sim [--n 8000] [--quick]
      times array vs seed engine over the e2e cell grid and writes
      BENCH_sim.json (events/sec, wall per cell, speedup) — the
      simulator perf trajectory is tracked through that file.
"""

from __future__ import annotations

import argparse
import time


def _closed_form_rows():
    from repro.core import timing as T

    rows = []
    t0 = time.perf_counter()
    red = T.per_step_reduction_pr2()
    dt = (time.perf_counter() - t0) * 1e6
    rows.append(
        ("timing/pr2_per_step", dt, f"reduction={100 * red:.1f}%;paper=28.5%")
    )
    for a in (1, 2, 4, 8):
        seq = float(T.sequential_read_latency(a))
        pipe = float(T.pipelined_read_latency(a))
        both = float(T.read_latency(a, "pr2ar2", tr_scale=0.75))
        rows.append(
            (
                f"timing/latency_a{a}",
                0.0,
                f"seq={seq:.1f}us;pr2={pipe:.1f}us;pr2ar2={both:.1f}us",
            )
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-e2e", action="store_true",
                    help="skip the (slow) discrete-event simulation table")
    ap.add_argument("--n", type=int, default=8000,
                    help="requests per e2e simulation run")
    ap.add_argument("--engine", choices=("array", "reference"),
                    default="array", help="DES engine for the e2e section")
    args = ap.parse_args()

    sections = []

    from benchmarks import ecc_margin, retry_characterization, tr_reduction

    print("# section: closed-form timing", flush=True)
    sections.append(_closed_form_rows())
    for row in sections[-1]:
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    print("# section: observation-1 retry characterization", flush=True)
    sections.append(retry_characterization.csv_rows())
    for row in sections[-1]:
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    print("# section: observation-2 ecc margin", flush=True)
    sections.append(ecc_margin.csv_rows())
    for row in sections[-1]:
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    print("# section: observation-3 tr reduction", flush=True)
    sections.append(tr_reduction.csv_rows())
    for row in sections[-1]:
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    if not args.skip_e2e:
        from benchmarks import e2e_response_time

        print("# section: e2e response time (DES)", flush=True)
        sections.append(e2e_response_time.csv_rows(args.n, engine=args.engine))
        for row in sections[-1]:
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    # Roofline table (requires dry-run artifacts; cheap to derive).
    try:
        from benchmarks import roofline

        print("# section: roofline (from dry-run artifacts)", flush=True)
        rows = roofline.csv_rows()
        sections.append(rows)
        for row in rows:
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
    except FileNotFoundError as e:
        print(f"# roofline skipped: {e}", flush=True)

    n = sum(len(s) for s in sections)
    print(f"# done: {n} rows", flush=True)


if __name__ == "__main__":
    main()
