"""Paper §5 headline results: end-to-end SSD response time.

Runs the event-driven multi-queue simulator over the six workload profiles
under an aged operating condition and compares mechanisms:

  * PR²+AR² vs the high-end-SSD baseline — paper: up to 50.8% response-time
    reduction, 35.7% on average;
  * SOTA[25]+PR²+AR² vs SOTA[25] alone — paper: further up to 31.5% /
    21.8% on average in read-dominant workloads.

Condition choice (the extended abstract does not publish the evaluation
grid; we validate each comparison where it is meaningful, and record the
choice in EXPERIMENTS.md):

  * vs the high-end baseline: an *aged* SSD (1-year retention, 1K P/E) —
    the regime the paper motivates (heavy retry);
  * vs SOTA [25]: *modest* conditions (1–3-month retention, low P/E) —
    where SOTA's history predictor is most effective, so the residual
    improvement isolates PR²+AR²'s per-step latency cuts.  At aged
    conditions SOTA leaves >= 3 retry steps per read (the paper's own §2
    critique) and PR²+AR²'s gain over it grows well beyond 21.8%; that
    aged number is also reported, flagged as beyond-paper.

Attempt counts come from the 160-chip characterization histograms, exactly
as the paper transplants real-device statistics into MQSim.

Usage: PYTHONPATH=src python -m benchmarks.e2e_response_time [--n 20000]
           [--seed 0] [--engine {array,reference}]

``--engine reference`` runs the retired seed engine (closure DES) instead
of the array event-core — used by benchmarks/microbench_sim.py to track
the array engine's speedup in BENCH_sim.json.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.flashsim.config import OperatingCondition
from repro.flashsim.ssd import compare_mechanisms
from repro.flashsim.workloads import PROFILES

AGED = OperatingCondition(retention_days=365.0, pec=1000.0)
#: vs-SOTA validation grid: fresh-to-1-month retention, where the SOTA
#: predictor mostly lands on a correctable entry immediately (mean attempts
#: ~1–2).  The paper's further-21.8% average is only attainable in that
#: regime — the per-read floor of PR²+AR² with a single attempt is already
#: -17.7% (AR²'s tR cut alone), and every retried read adds the pipelined
#: step savings on top.  At aged conditions SOTA leaves >= 3 steps per read
#: (the paper's §2 critique) and the gain compounds well past the paper's
#: figure; reported separately as beyond-paper.
MODEST = (
    OperatingCondition(retention_days=0.0, pec=0.0),
    OperatingCondition(retention_days=7.0, pec=0.0),
    OperatingCondition(retention_days=30.0, pec=0.0),
)

PAPER_AVG_VS_BASELINE = 0.357
PAPER_MAX_VS_BASELINE = 0.508
PAPER_AVG_VS_SOTA = 0.218
PAPER_MAX_VS_SOTA = 0.315
TOL = 0.08  # absolute tolerance on reduction fractions (DES + trace noise)


def run(n_requests: int = 20000, seed: int = 0, verbose: bool = True,
        engine: str = "array"):
    mechs = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")
    all_rows = []
    t_start = time.perf_counter()

    # --- vs high-end baseline: aged SSD, all six workloads ---------------
    red_base, red_sota_aged = [], []
    for w in PROFILES:
        t0 = time.perf_counter()
        stats = compare_mechanisms(
            w, AGED, mechanisms=mechs, seed=seed, n_requests=n_requests,
            engine=engine,
        )
        dt = (time.perf_counter() - t0) * 1e6
        r_b = 1.0 - stats["pr2ar2"].mean_us / stats["baseline"].mean_us
        r_s = 1.0 - stats["sota+pr2ar2"].mean_us / stats["sota"].mean_us
        red_base.append(r_b)
        if w.read_dominant:
            red_sota_aged.append(r_s)
        all_rows.append((w, AGED, stats, r_b, r_s, dt))
        if verbose:
            print(f"  [{w.name:10s} @ {AGED.label():>10s}] read_ratio={w.read_ratio:.2f}")
            for m in mechs:
                print(f"    {m:12s} {stats[m].as_row()}")
            print(
                f"    -> PR2+AR2 vs baseline: -{100 * r_b:5.1f}% | "
                f"SOTA+PR2+AR2 vs SOTA: -{100 * r_s:5.1f}%"
            )

    # --- vs SOTA: modest conditions, read-dominant workloads -------------
    red_sota = []
    for cond in MODEST:
        for w in (w for w in PROFILES if w.read_dominant):
            t0 = time.perf_counter()
            stats = compare_mechanisms(
                w, cond, mechanisms=("sota", "sota+pr2ar2"),
                seed=seed, n_requests=n_requests, engine=engine,
            )
            dt = (time.perf_counter() - t0) * 1e6
            r_s = 1.0 - stats["sota+pr2ar2"].mean_us / stats["sota"].mean_us
            red_sota.append(r_s)
            all_rows.append((w, cond, stats, None, r_s, dt))
            if verbose:
                print(
                    f"  [{w.name:10s} @ {cond.label():>10s}] "
                    f"SOTA {stats['sota'].mean_us:8.1f}us -> "
                    f"+PR2+AR2 {stats['sota+pr2ar2'].mean_us:8.1f}us "
                    f"(-{100 * r_s:5.1f}%)"
                )

    avg_b, max_b = float(np.mean(red_base)), float(np.max(red_base))
    avg_s, max_s = float(np.mean(red_sota)), float(np.max(red_sota))
    avg_s_aged = float(np.mean(red_sota_aged))
    ok = (
        abs(avg_b - PAPER_AVG_VS_BASELINE) <= TOL
        and abs(max_b - PAPER_MAX_VS_BASELINE) <= TOL + 0.04
        and abs(avg_s - PAPER_AVG_VS_SOTA) <= TOL
        and abs(max_s - PAPER_MAX_VS_SOTA) <= TOL + 0.04
    )
    if verbose:
        print(
            f"paper check: vs baseline (aged) avg -{100 * avg_b:.1f}% "
            f"(paper -35.7%), max -{100 * max_b:.1f}% (paper -50.8%)"
        )
        print(
            f"             vs SOTA (modest, read-dominant) avg -{100 * avg_s:.1f}% "
            f"(paper -21.8%), max -{100 * max_s:.1f}% (paper -31.5%) "
            f"-> {'OK' if ok else 'MISMATCH'}"
        )
        print(
            f"             beyond-paper: vs SOTA at aged condition "
            f"-{100 * avg_s_aged:.1f}% avg (SOTA leaves >=3 steps there, "
            f"so per-step cuts compound)"
        )
        print(
            f"wall: {time.perf_counter() - t_start:.1f}s total "
            f"({engine} engine)"
        )
    return all_rows, (avg_b, max_b, avg_s, max_s, ok)


def csv_rows(n_requests: int = 8000, engine: str = "array"):
    rows, (avg_b, max_b, avg_s, max_s, ok) = run(
        n_requests, verbose=False, engine=engine
    )
    out = []
    for w, cond, stats, r_b, r_s, dt in rows:
        if r_b is not None:
            derived = (
                f"base={stats['baseline'].mean_us:.0f}us;"
                f"pr2ar2={stats['pr2ar2'].mean_us:.0f}us;"
                f"vs_base=-{100 * r_b:.1f}%;vs_sota=-{100 * r_s:.1f}%"
            )
        else:
            derived = (
                f"sota={stats['sota'].mean_us:.0f}us;"
                f"sota_pr2ar2={stats['sota+pr2ar2'].mean_us:.0f}us;"
                f"vs_sota=-{100 * r_s:.1f}%"
            )
        out.append((f"e2e/{w.name}@{cond.label()}", dt, derived))
    out.append(
        (
            "e2e/summary",
            0.0,
            f"avg_vs_base=-{100 * avg_b:.1f}%;max=-{100 * max_b:.1f}%;"
            f"avg_vs_sota=-{100 * avg_s:.1f}%;max=-{100 * max_s:.1f}%;ok={ok}",
        )
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", choices=("array", "reference"),
                    default="array",
                    help="DES engine: array event-core or the seed "
                         "closure engine (for speedup tracking)")
    args = ap.parse_args()
    print(
        f"E2E response time — 6 workloads @ {AGED.label()} (vs baseline) + "
        f"read-dominant @ modest conditions (vs SOTA), {args.n} requests each"
    )
    _, (_, _, _, _, ok) = run(args.n, args.seed, engine=args.engine)
    if not ok:
        raise SystemExit("paper-claim validation failed")


if __name__ == "__main__":
    main()
