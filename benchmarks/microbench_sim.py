"""Simulator engine microbenchmark — emits ``BENCH_sim.json``.

Times the array event-core (repro.flashsim.ssd.SSDSim) against the retired
seed engine (repro.flashsim.engine_ref.SSDSimRef) on the exact cell grid
of ``benchmarks/e2e_response_time``:

  * 6 workloads @ aged (1y retention / 1K P/E) x 6 mechanisms, and
  * read-dominant workloads @ 3 modest conditions x {sota, sota+pr2ar2},

with every characterization table warmed first, so the recorded numbers
isolate the DES hot path.  The seed path is measured faithfully to the
original ``compare_mechanisms``: the trace is regenerated per mechanism
and attempt counts are sampled per request inside the engine; the array
path shares one trace + expansion per cell and samples attempts in one
batched pass.

``BENCH_sim.json`` records per-cell wall times, event counts, events/sec,
and the aggregate speedup — the perf trajectory of the simulator is
tracked through this file from PR 1 onward.

A GC sweep (PR 2) rides along: each write-heavy profile runs with the
page-mapping FTL off and on, recording write amplification, GC traffic,
and the host-read p99 inflation GC contention causes — the acceptance
check is WA > 1.0 and strictly higher host-read p99 with GC enabled.

Usage: PYTHONPATH=src python -m benchmarks.microbench_sim [--n 8000]
           [--quick] [--skip-reference] [--skip-gc] [--out BENCH_sim.json]

  --n N             requests per cell (default 8000, the acceptance size)
  --quick           tiny grid + small n (CI smoke; implies --n 1200)
  --skip-reference  only measure the array engine (no speedup column)
  --skip-gc         skip the FTL/GC sweep cells
  --out PATH        output JSON path (default BENCH_sim.json in cwd)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core.retry import RetryPolicy
from repro.flashsim.config import GCConfig, SSDConfig
from repro.flashsim.engine_ref import SSDSimRef
from repro.flashsim.ssd import SSDSim, expand_trace, simulate
from repro.flashsim.workloads import (
    GC_PROFILES,
    PROFILES,
    cached_trace,
    generate_trace,
)

from benchmarks.e2e_response_time import AGED, MODEST

ALL_MECHS = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")

#: Requests per GC cell in --quick mode.  GC intensity is non-monotonic
#: in trace length (capacity auto-sizes with the footprint, which grows
#: with n); 2500 sits past the near-dead zone around ~2k requests, where
#: both write-heavy presets reliably churn (prn: ~100 invocations,
#: rsrch: ~300 at seed 0).
GC_QUICK_N = 2500


def e2e_cells(quick: bool = False):
    """The (workload, condition, mechanisms) grid of the e2e benchmark."""
    cells = []
    profiles = PROFILES[:2] if quick else PROFILES
    for w in profiles:
        cells.append((w, AGED, ALL_MECHS))
    modest = MODEST[:1] if quick else MODEST
    for cond in modest:
        for w in (w for w in profiles if w.read_dominant):
            cells.append((w, cond, ("sota", "sota+pr2ar2")))
    return cells


def warm_characterization(cells):
    """Build every (condition, mechanism) attempt table before timing."""
    t0 = time.perf_counter()
    for _, cond, mechs in cells:
        for m in mechs:
            SSDSim(condition=cond, policy=RetryPolicy(m))
    return time.perf_counter() - t0


def bench_cell(w, cond, mechs, n_requests, seed, skip_reference):
    w = dataclasses.replace(w, n_requests=n_requests)

    # Array path: one trace + one expansion shared by all mechanisms.
    t0 = time.perf_counter()
    trace = cached_trace(w, seed=seed)
    expansion = expand_trace(trace)
    events_array = 0
    stats_array = {}
    for m in mechs:
        sim = SSDSim(condition=cond, policy=RetryPolicy(m), seed=seed + 7)
        stats_array[m] = sim.run(trace, expansion=expansion)
        events_array += sim.events_processed
    wall_array = time.perf_counter() - t0

    row = {
        "workload": w.name,
        "condition": cond.label(),
        "mechanisms": list(mechs),
        "n_requests": n_requests,
        "wall_array_s": round(wall_array, 4),
        "events_array": events_array,
        "events_per_sec_array": round(events_array / wall_array),
    }

    if not skip_reference:
        # Seed path, faithful to the original compare_mechanisms: trace
        # regenerated per mechanism, per-request sampling in the engine.
        t0 = time.perf_counter()
        events_ref = 0
        stats_ref = {}
        for m in mechs:
            trace_m = generate_trace(w, seed=seed)
            ref = SSDSimRef(condition=cond, policy=RetryPolicy(m),
                            seed=seed + 7)
            stats_ref[m] = ref.run(trace_m)
            events_ref += ref.events_processed
        wall_ref = time.perf_counter() - t0
        row["wall_seed_s"] = round(wall_ref, 4)
        row["events_seed"] = events_ref
        row["speedup"] = round(wall_ref / wall_array, 2)
        # Cross-engine sanity: identical attempt statistics per mechanism.
        row["attempts_match"] = all(
            abs(stats_array[m].mean_read_attempts
                - stats_ref[m].mean_read_attempts) < 1e-9
            for m in mechs
        )
    return row


def bench_gc_cell(w, cond, n_requests, seed):
    """FTL off vs on for one write-heavy profile: WA + read-tail impact.

    Runs baseline and pr2ar2 under both configurations so the row also
    records how much of the GC-induced read tail the paper's combined
    mechanism claws back.
    """
    w = dataclasses.replace(w, n_requests=n_requests)
    cfg_gc = SSDConfig(gc=GCConfig(enabled=True))
    row = {
        "workload": w.name,
        "condition": cond.label(),
        "n_requests": n_requests,
        "span_pages": w.span_pages,
    }
    for mech in ("baseline", "pr2ar2"):
        t0 = time.perf_counter()
        off = simulate(w, cond, mech, seed=seed)
        t1 = time.perf_counter()
        on = simulate(w, cond, mech, seed=seed, cfg=cfg_gc)
        t2 = time.perf_counter()
        row[mech] = {
            "wall_off_s": round(t1 - t0, 4),
            "wall_on_s": round(t2 - t1, 4),
            "read_p99_off_us": round(off.read_p99_us, 1),
            "read_p99_on_us": round(on.read_p99_us, 1),
            "read_p99_inflation": round(on.read_p99_us / off.read_p99_us, 2),
            "mean_off_us": round(off.mean_us, 1),
            "mean_on_us": round(on.mean_us, 1),
            "die_util_on": round(on.die_util, 3),
        }
        if mech == "baseline":
            row.update(
                wa=round(on.wa, 3),
                gc_invocations=on.gc_invocations,
                gc_page_reads=on.gc_page_reads,
                gc_page_progs=on.gc_page_progs,
                blocks_erased=on.blocks_erased,
            )
    # The acceptance properties of the FTL subsystem:
    row["ok_wa_gt_1"] = row["wa"] > 1.0
    row["ok_read_p99_higher"] = all(
        row[m]["read_p99_on_us"] > row[m]["read_p99_off_us"]
        for m in ("baseline", "pr2ar2")
    )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-reference", action="store_true")
    ap.add_argument("--skip-gc", action="store_true")
    ap.add_argument("--out", default="BENCH_sim.json")
    args = ap.parse_args()
    n = 1200 if args.quick else args.n

    cells = e2e_cells(args.quick)
    warm_s = warm_characterization(cells)
    print(f"# characterization warm: {warm_s:.1f}s ({len(cells)} cells)")

    rows = []
    for w, cond, mechs in cells:
        row = bench_cell(w, cond, mechs, n, args.seed, args.skip_reference)
        rows.append(row)
        spd = f" speedup={row['speedup']:5.2f}x" if "speedup" in row else ""
        print(
            f"{w.name:10s} @ {cond.label():>10s} x{len(mechs)} mechs: "
            f"array {row['wall_array_s']:6.3f}s "
            f"({row['events_per_sec_array'] / 1e6:.2f}M ev/s){spd}"
        )

    gc_rows = []
    gc_carried = False
    if args.skip_gc:
        # Don't clobber the recorded GC trajectory: carry the previous
        # file's GC cells forward (flagged so readers know they're stale).
        try:
            with open(args.out) as f:
                gc_rows = json.load(f).get("gc_cells", [])
            gc_carried = bool(gc_rows)
        except (OSError, ValueError):
            pass
    else:
        n_gc = GC_QUICK_N if args.quick else n
        gc_profiles = GC_PROFILES[:1] if args.quick else GC_PROFILES
        for w in gc_profiles:
            row = bench_gc_cell(w, AGED, n_gc, args.seed)
            gc_rows.append(row)
            print(
                f"GC {w.name:8s} @ {row['condition']:>10s}: "
                f"WA={row['wa']:.2f} gc_inv={row['gc_invocations']} "
                f"read_p99 x{row['baseline']['read_p99_inflation']:.1f} "
                f"(pr2ar2 x{row['pr2ar2']['read_p99_inflation']:.1f}) "
                f"ok={row['ok_wa_gt_1'] and row['ok_read_p99_higher']}"
            )

    total_array = sum(r["wall_array_s"] for r in rows)
    summary = {
        "n_requests": n,
        "cells": len(rows),
        "wall_array_total_s": round(total_array, 3),
        "events_per_sec_array": round(
            sum(r["events_array"] for r in rows) / total_array
        ),
        "characterization_warm_s": round(warm_s, 2),
    }
    if not args.skip_reference:
        total_ref = sum(r["wall_seed_s"] for r in rows)
        summary["wall_seed_total_s"] = round(total_ref, 3)
        summary["speedup_total"] = round(total_ref / total_array, 2)
        summary["attempts_match_all"] = all(r["attempts_match"] for r in rows)
    if gc_rows:
        summary["gc_wa_max"] = max(r["wa"] for r in gc_rows)
        summary["gc_acceptance_ok"] = all(
            r["ok_wa_gt_1"] and r["ok_read_p99_higher"] for r in gc_rows
        )
        if gc_carried:
            summary["gc_cells_carried"] = True  # from a previous run

    out = {"benchmark": "flashsim-des-engine", "summary": summary,
           "cells_detail": rows, "gc_cells": gc_rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# summary: {json.dumps(summary)}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
