"""Simulator engine microbenchmark — emits ``BENCH_sim.json``.

Times the array event-core (repro.flashsim.ssd.SSDSim) against the retired
seed engine (repro.flashsim.engine_ref.SSDSimRef) on the exact cell grid
of ``benchmarks/e2e_response_time``:

  * 6 workloads @ aged (1y retention / 1K P/E) x 6 mechanisms, and
  * read-dominant workloads @ 3 modest conditions x {sota, sota+pr2ar2},

with every characterization table warmed first, so the recorded numbers
isolate the DES hot path.  The seed path is measured faithfully to the
original ``compare_mechanisms``: the trace is regenerated per mechanism
and attempt counts are sampled per request inside the engine; the array
path shares one trace + expansion per cell and samples attempts in one
batched pass.

``BENCH_sim.json`` records per-cell wall times, event counts, events/sec,
and the aggregate speedup — the perf trajectory of the simulator is
tracked through this file from PR 1 onward.

Usage: PYTHONPATH=src python -m benchmarks.microbench_sim [--n 8000]
           [--quick] [--skip-reference] [--out BENCH_sim.json]

  --n N             requests per cell (default 8000, the acceptance size)
  --quick           tiny grid + small n (CI smoke; implies --n 1200)
  --skip-reference  only measure the array engine (no speedup column)
  --out PATH        output JSON path (default BENCH_sim.json in cwd)
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core.retry import RetryPolicy
from repro.flashsim.config import OperatingCondition
from repro.flashsim.engine_ref import SSDSimRef
from repro.flashsim.ssd import SSDSim, expand_trace
from repro.flashsim.workloads import PROFILES, cached_trace, generate_trace

from benchmarks.e2e_response_time import AGED, MODEST

ALL_MECHS = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")


def e2e_cells(quick: bool = False):
    """The (workload, condition, mechanisms) grid of the e2e benchmark."""
    cells = []
    profiles = PROFILES[:2] if quick else PROFILES
    for w in profiles:
        cells.append((w, AGED, ALL_MECHS))
    modest = MODEST[:1] if quick else MODEST
    for cond in modest:
        for w in (w for w in profiles if w.read_dominant):
            cells.append((w, cond, ("sota", "sota+pr2ar2")))
    return cells


def warm_characterization(cells):
    """Build every (condition, mechanism) attempt table before timing."""
    t0 = time.perf_counter()
    for _, cond, mechs in cells:
        for m in mechs:
            SSDSim(condition=cond, policy=RetryPolicy(m))
    return time.perf_counter() - t0


def bench_cell(w, cond, mechs, n_requests, seed, skip_reference):
    w = dataclasses.replace(w, n_requests=n_requests)

    # Array path: one trace + one expansion shared by all mechanisms.
    t0 = time.perf_counter()
    trace = cached_trace(w, seed=seed)
    expansion = expand_trace(trace)
    events_array = 0
    stats_array = {}
    for m in mechs:
        sim = SSDSim(condition=cond, policy=RetryPolicy(m), seed=seed + 7)
        stats_array[m] = sim.run(trace, expansion=expansion)
        events_array += sim.events_processed
    wall_array = time.perf_counter() - t0

    row = {
        "workload": w.name,
        "condition": cond.label(),
        "mechanisms": list(mechs),
        "n_requests": n_requests,
        "wall_array_s": round(wall_array, 4),
        "events_array": events_array,
        "events_per_sec_array": round(events_array / wall_array),
    }

    if not skip_reference:
        # Seed path, faithful to the original compare_mechanisms: trace
        # regenerated per mechanism, per-request sampling in the engine.
        t0 = time.perf_counter()
        events_ref = 0
        stats_ref = {}
        for m in mechs:
            trace_m = generate_trace(w, seed=seed)
            ref = SSDSimRef(condition=cond, policy=RetryPolicy(m),
                            seed=seed + 7)
            stats_ref[m] = ref.run(trace_m)
            events_ref += ref.events_processed
        wall_ref = time.perf_counter() - t0
        row["wall_seed_s"] = round(wall_ref, 4)
        row["events_seed"] = events_ref
        row["speedup"] = round(wall_ref / wall_array, 2)
        # Cross-engine sanity: identical attempt statistics per mechanism.
        row["attempts_match"] = all(
            abs(stats_array[m].mean_read_attempts
                - stats_ref[m].mean_read_attempts) < 1e-9
            for m in mechs
        )
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=8000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-reference", action="store_true")
    ap.add_argument("--out", default="BENCH_sim.json")
    args = ap.parse_args()
    n = 1200 if args.quick else args.n

    cells = e2e_cells(args.quick)
    warm_s = warm_characterization(cells)
    print(f"# characterization warm: {warm_s:.1f}s ({len(cells)} cells)")

    rows = []
    for w, cond, mechs in cells:
        row = bench_cell(w, cond, mechs, n, args.seed, args.skip_reference)
        rows.append(row)
        spd = f" speedup={row['speedup']:5.2f}x" if "speedup" in row else ""
        print(
            f"{w.name:10s} @ {cond.label():>10s} x{len(mechs)} mechs: "
            f"array {row['wall_array_s']:6.3f}s "
            f"({row['events_per_sec_array'] / 1e6:.2f}M ev/s){spd}"
        )

    total_array = sum(r["wall_array_s"] for r in rows)
    summary = {
        "n_requests": n,
        "cells": len(rows),
        "wall_array_total_s": round(total_array, 3),
        "events_per_sec_array": round(
            sum(r["events_array"] for r in rows) / total_array
        ),
        "characterization_warm_s": round(warm_s, 2),
    }
    if not args.skip_reference:
        total_ref = sum(r["wall_seed_s"] for r in rows)
        summary["wall_seed_total_s"] = round(total_ref, 3)
        summary["speedup_total"] = round(total_ref / total_array, 2)
        summary["attempts_match_all"] = all(r["attempts_match"] for r in rows)

    out = {"benchmark": "flashsim-des-engine", "summary": summary,
           "cells_detail": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# summary: {json.dumps(summary)}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
