"""Simulator engine microbenchmark — emits ``BENCH_sim.json``.

Times the array event-core (repro.flashsim.ssd.SSDSim) against the retired
seed engine (repro.flashsim.engine_ref.SSDSimRef) on the exact cell grid
of ``benchmarks/e2e_response_time``:

  * 6 workloads @ aged (1y retention / 1K P/E) x 6 mechanisms, and
  * read-dominant workloads @ 3 modest conditions x {sota, sota+pr2ar2},

with every characterization table warmed first, so the recorded numbers
isolate the DES hot path.  The seed path is measured faithfully to the
original ``compare_mechanisms``: the trace is regenerated per mechanism
and attempt counts are sampled per request inside the engine; the array
path shares one trace + expansion per cell and samples attempts in one
batched pass.

``BENCH_sim.json`` records per-cell wall times, event counts, events/sec,
and the aggregate speedup — the perf trajectory of the simulator is
tracked through this file from PR 1 onward.  Because those absolute
numbers are machine-dependent, every file also records (PR 5):

  * a **host fingerprint** (CPU model, core count, python/numpy
    versions) — a BENCH_sim.json measured on a different machine class
    is visibly a different machine, not a regression;
  * a **pinned reference cell** re-measured in the same run: the first
    e2e cell's events/sec divided into every other cell
    (``rel_throughput``) cancels the machine entirely, and
    ``host_factor`` (measured / pinned reference throughput at the
    acceptance size) quantifies how the current host compares to the
    machine class that set the in-repo pin.  Cross-machine comparisons
    should use ``rel_throughput`` and ``host_factor``-normalized
    numbers, never raw wall times.

Seven sweeps ride along:

  * **claim cells** (PR 3): the paper's headline reductions (PR²+AR² vs
    baseline @ aged; SOTA+PR²+AR² vs SOTA @ modest) re-measured as
    mean ± 95% CI over ``--seeds`` independent traces, with the paper
    check as a CI-overlap test instead of a point comparison;
  * **GC cells** (PR 2, multi-seed since PR 3): each write-heavy profile
    with the page-mapping FTL off and on — write amplification and the
    host-read p99 inflation GC contention causes, mean ± 95% CI;
  * **scheduler cells** (PR 3): the GC profiles under online GC across
    the die-queue policies (fcfs / host_prio / preempt) — the
    host-read-priority acceptance: host_prio and preempt must cut the
    fcfs read-p99 inflation by >= 2x at equal (±10%) WA;
  * **workload (real-trace replay) cells** (PR 4): the checked-in
    MSR-format excerpts (tests/data) replayed end-to-end through the
    ingestion -> dense-remap -> FTL-auto-sizing path, baseline vs
    PR²/AR² with prepass GC.  Seed variation comes from an 0.85
    Bernoulli subsample per seed (deterministic files have no seed of
    their own), reported as mean ± 95% CI; the acceptance is that every
    mechanism produces finite stats and the FTL engages (WA > 1);
  * **fault cells** (PR 6): read-dominant profiles @ aged under the
    seeded fault model (:mod:`repro.flashsim.faults`) across a
    ``mispredict_scale`` ladder — the AR² misprediction-rate vs
    latency-win tradeoff (mean ± 95% CI over seeds) plus the
    recovery-latency p99.  The acceptance: mispredictions actually fire
    at the derived rate, the win erodes (never inverts) as the rate
    grows, and nothing is unrecoverable at the paper-default ECC margin;
  * **shard-scaling cells** (PR 8, extended PR 9): the batched lockstep
    core (``engine="batched"``) vs the array interpreter, wall vs
    channel count {1, 2, 4, 8} on the websearch reference cell —
    per-cell bit-parity (full SimStats equality per seed) and
    fast-path-activated flags, best-of-3 walls as mean ± 95% CI over
    seeds, throughput normalized to this run's 8-channel array cell.
    The acceptance rides on the 8-channel cell: batched events/sec
    >= 1.5x the interpreter.  Since PR 9 the block also carries
    ``scheduler_cells_8ch`` (the 8-channel cell under the dual priority
    rings — host_prio / host_prio_aged — acceptance: batched >= 1.3x
    under host_prio) and ``small_cell_sweep`` (an n=500 grid through
    ``run_cells`` at ``engine="array"`` vs ``engine="auto"``: auto must
    select batched everywhere and the batched sweep wall must not lose
    — the dispatch-overhead gate);
  * **fused sweep cells** (PR 10): the cross-cell fused dispatch path
    vs the sequential batched engine vs the array interpreter on two
    (mechanism x condition x seed) grids through ``run_cells`` — the
    n=500 small-cell grid where fixed dispatch cost dominates (the
    acceptance: fused >= 1.5x the sequential batched sweep wall with
    full per-cell bit parity against both other variants) and an
    n=8000 claim grid where the lockstep loop dominates (recorded, not
    gated).  Walls are interleaved rounds with the collector parked
    (mean ± 95% CI + best); kernel-launch accounting
    (``fused_dispatches`` vs ``sequential_dispatches``) pins that the
    speedup is amortized dispatch overhead, not changed math.

The claim/GC/scheduler/trace sweeps all execute through the parallel
sweep runtime (:mod:`repro.flashsim.runtime`); ``--workers N`` fans
their cells across a process pool.  With ``N > 1`` the paper-claim grid
is additionally re-run at ``workers=1`` and the file records the
measured ``speedup`` plus a ``cells_equal`` flag (per-cell results must
be identical for every worker count — the CI bench-smoke lane asserts
byte-equality of the deterministic payload between a workers=1 and a
workers=2 run via ``benchmarks/bench_compare.py``).  On a single-core
host (fingerprint ``cpu_count < 2``) the parallel block is gated: it
records ``skipped`` + ``skipped_reason`` instead of a speedup that
could only measure process overhead.

Usage: PYTHONPATH=src python -m benchmarks.microbench_sim [--n 8000]
           [--seeds 5] [--quick] [--workers 4] [--skip-reference]
           [--skip-gc] [--skip-traces] [--out BENCH_sim.json]

  --n N             requests per cell (default 8000, the acceptance size)
  --seeds K         seeds per claim/GC/scheduler/workload cell (default 5)
  --quick           tiny grid (CI smoke; n defaults to 1200, 2 seeds)
  --workers N       process-pool workers for the sweep cells (default 4;
                    1 in --quick); N > 1 also records the parallel-sweep
                    speedup block
  --skip-reference  only measure the array engine (no speedup column)
  --skip-gc         skip the FTL/GC + scheduler sweep cells
  --skip-traces     skip the real-trace replay cells
  --out PATH        output JSON path (default BENCH_sim.json in cwd)
"""

from __future__ import annotations

import argparse
import dataclasses
import gc
import json
import math
import time

import numpy as np

from repro.core.retry import RetryPolicy
from repro.flashsim.config import (DEFAULT_SSD, FaultConfig, GCConfig,
                                   HostCacheConfig, SSDConfig)
from repro.flashsim.engine_ref import SSDSimRef
from repro.flashsim.runtime import Cell, host_fingerprint, run_cells
from repro.flashsim.ssd import (
    SSDSim,
    expand_trace,
    simulate_batch,
)
from repro.flashsim.workloads import (
    GC_PROFILES,
    PROFILES,
    Subsample,
    cached_trace,
    generate_trace,
    get_source,
    trace_stats,
)

from benchmarks.e2e_response_time import (
    AGED,
    MODEST,
    PAPER_AVG_VS_BASELINE,
    PAPER_AVG_VS_SOTA,
    PAPER_MAX_VS_BASELINE,
    PAPER_MAX_VS_SOTA,
    TOL,
)

ALL_MECHS = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")
SCHED_POLICIES = ("fcfs", "host_prio", "preempt")

#: The pinned reference cell: the FIRST e2e cell (websearch @ aged x all
#: six mechanisms) at the acceptance size REFERENCE_N, re-measured in
#: every run.  REFERENCE_EVENTS_PER_SEC is its array-engine throughput
#: on the machine class that set the pin (PR 5); host_factor =
#: measured / pinned tells every later reader how fast the current host
#: is relative to that class, and per-cell ``rel_throughput`` (cell
#: ev/s / reference-cell ev/s, same run) is machine-independent.
REFERENCE_N = 8000
REFERENCE_EVENTS_PER_SEC = 395_000

#: Requests per GC cell in --quick mode.  GC intensity is non-monotonic
#: in trace length (capacity auto-sizes with the footprint, which grows
#: with n); 2500 sits past the near-dead zone around ~2k requests, where
#: both write-heavy presets reliably churn (prn: ~100 invocations,
#: rsrch: ~300 at seed 0).
GC_QUICK_N = 2500

#: Two-sided 95% t critical values by degrees of freedom (n_seeds - 1).
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 11: 2.201, 12: 2.179,
        13: 2.160, 14: 2.145, 15: 2.131, 16: 2.120, 17: 2.110, 18: 2.101,
        19: 2.093, 20: 2.086}


def mean_ci95(xs):
    """(mean, 95% CI half-width) of a small sample (t-distribution).

    One seed yields a degenerate (mean, 0.0) — the claim check then
    reduces to a point comparison.  Beyond 21 seeds the critical value
    is approximated by the dof=30 entry (2.042, within 1% of the true
    value for any larger sample; never the understating z=1.96).
    """
    xs = np.asarray(list(xs), dtype=float)
    n = xs.size
    m = float(xs.mean())
    if n < 2:
        return m, 0.0
    t = _T95.get(n - 1, 2.042)
    return m, t * float(xs.std(ddof=1)) / math.sqrt(n)


def ci_overlaps(mean, half, target, tol):
    """CI-overlap test: [mean±half] intersects [target±tol]."""
    return mean - half <= target + tol and target - tol <= mean + half


# -- engine timing cells (single-seed; the PR 1 speedup trajectory) -------


def e2e_cells(quick: bool = False):
    """The (workload, condition, mechanisms) grid of the e2e benchmark."""
    cells = []
    profiles = PROFILES[:2] if quick else PROFILES
    for w in profiles:
        cells.append((w, AGED, ALL_MECHS))
    modest = MODEST[:1] if quick else MODEST
    for cond in modest:
        for w in (w for w in profiles if w.read_dominant):
            cells.append((w, cond, ("sota", "sota+pr2ar2")))
    return cells


def warm_characterization(cells):
    """Build every (condition, mechanism) attempt table before timing."""
    t0 = time.perf_counter()
    for _, cond, mechs in cells:
        for m in mechs:
            SSDSim(condition=cond, policy=RetryPolicy(m))
    return time.perf_counter() - t0


def bench_cell(w, cond, mechs, n_requests, seed, skip_reference):
    w = dataclasses.replace(w, n_requests=n_requests)

    # Array path: one trace + one expansion shared by all mechanisms.
    t0 = time.perf_counter()
    trace = cached_trace(w, seed=seed)
    expansion = expand_trace(trace)
    events_array = 0
    stats_array = {}
    for m in mechs:
        sim = SSDSim(condition=cond, policy=RetryPolicy(m), seed=seed + 7)
        stats_array[m] = sim.run(trace, expansion=expansion)
        events_array += sim.events_processed
    wall_array = time.perf_counter() - t0

    row = {
        "workload": w.name,
        "condition": cond.label(),
        "mechanisms": list(mechs),
        "n_requests": n_requests,
        "wall_array_s": round(wall_array, 4),
        "events_array": events_array,
        "events_per_sec_array": round(events_array / wall_array),
    }

    if not skip_reference:
        # Seed path, faithful to the original compare_mechanisms: trace
        # regenerated per mechanism, per-request sampling in the engine.
        t0 = time.perf_counter()
        events_ref = 0
        stats_ref = {}
        for m in mechs:
            trace_m = generate_trace(w, seed=seed)
            ref = SSDSimRef(condition=cond, policy=RetryPolicy(m),
                            seed=seed + 7)
            stats_ref[m] = ref.run(trace_m)
            events_ref += ref.events_processed
        wall_ref = time.perf_counter() - t0
        row["wall_seed_s"] = round(wall_ref, 4)
        row["events_seed"] = events_ref
        row["speedup"] = round(wall_ref / wall_array, 2)
        # Cross-engine sanity: identical attempt statistics per mechanism.
        row["attempts_match"] = all(
            abs(stats_array[m].mean_read_attempts
                - stats_ref[m].mean_read_attempts) < 1e-9
            for m in mechs
        )
    return row


# -- paper-claim cells: mean ± 95% CI over seeds --------------------------


def bench_claim_cells(n_requests, seeds, quick=False, workers=1):
    """Re-measure the paper's headline reductions across >= 2 seeds.

    Per seed: the PR²+AR²-vs-baseline reduction averaged over the six
    profiles @ aged, and the SOTA+PR²+AR²-vs-SOTA reduction averaged
    over read-dominant profiles @ modest conditions.  The claim check is
    a CI-overlap test against the paper figure ± the historical point
    tolerance.
    """
    profiles = PROFILES[:3] if quick else PROFILES
    modest = MODEST[:1] if quick else MODEST
    per_workload = []
    red_base = {s: [] for s in seeds}   # seed -> per-workload reductions
    red_sota = {s: [] for s in seeds}
    for w in profiles:
        grid = simulate_batch(
            w, (AGED,), mechanisms=("baseline", "pr2ar2"),
            seeds=seeds, n_requests=n_requests, workers=workers,
        )
        rs = [
            1.0 - grid[("pr2ar2", AGED, s)].mean_us
            / grid[("baseline", AGED, s)].mean_us
            for s in seeds
        ]
        for s, r in zip(seeds, rs):
            red_base[s].append(r)
        m, h = mean_ci95(rs)
        per_workload.append({
            "workload": w.name, "condition": AGED.label(),
            "metric": "pr2ar2_vs_baseline",
            "mean_reduction": round(m, 4), "ci95": round(h, 4),
            "n_seeds": len(seeds),
        })
    for w in (w for w in profiles if w.read_dominant):
        grid = simulate_batch(
            w, modest, mechanisms=("sota", "sota+pr2ar2"),
            seeds=seeds, n_requests=n_requests, workers=workers,
        )
        for cond in modest:
            rs = [
                1.0 - grid[("sota+pr2ar2", cond, s)].mean_us
                / grid[("sota", cond, s)].mean_us
                for s in seeds
            ]
            for s, r in zip(seeds, rs):
                red_sota[s].append(r)
            m, h = mean_ci95(rs)
            per_workload.append({
                "workload": w.name, "condition": cond.label(),
                "metric": "sota+pr2ar2_vs_sota",
                "mean_reduction": round(m, 4), "ci95": round(h, 4),
                "n_seeds": len(seeds),
            })

    # Per-seed grid averages -> CI over seeds (seed = independent trace).
    avg_b = [float(np.mean(red_base[s])) for s in seeds]
    max_b = [float(np.max(red_base[s])) for s in seeds]
    avg_s = [float(np.mean(red_sota[s])) for s in seeds]
    max_s = [float(np.max(red_sota[s])) for s in seeds]
    mb, hb = mean_ci95(avg_b)
    mxb, hxb = mean_ci95(max_b)
    ms, hs = mean_ci95(avg_s)
    mxs, hxs = mean_ci95(max_s)
    summary = {
        "n_seeds": len(seeds),
        "avg_vs_baseline": {"mean": round(mb, 4), "ci95": round(hb, 4),
                            "paper": PAPER_AVG_VS_BASELINE},
        "max_vs_baseline": {"mean": round(mxb, 4), "ci95": round(hxb, 4),
                            "paper": PAPER_MAX_VS_BASELINE},
        "avg_vs_sota": {"mean": round(ms, 4), "ci95": round(hs, 4),
                        "paper": PAPER_AVG_VS_SOTA},
        "max_vs_sota": {"mean": round(mxs, 4), "ci95": round(hxs, 4),
                        "paper": PAPER_MAX_VS_SOTA},
        "claim_ci_overlap_ok": bool(
            ci_overlaps(mb, hb, PAPER_AVG_VS_BASELINE, TOL)
            and ci_overlaps(mxb, hxb, PAPER_MAX_VS_BASELINE, TOL + 0.04)
            and ci_overlaps(ms, hs, PAPER_AVG_VS_SOTA, TOL)
            and ci_overlaps(mxs, hxs, PAPER_MAX_VS_SOTA, TOL + 0.04)
        ),
    }
    return per_workload, summary


# -- GC cells: FTL off/on, mean ± CI over seeds ---------------------------


def bench_gc_cell(w, cond, n_requests, seeds, workers=1):
    """FTL off vs on for one write-heavy profile: WA + read-tail impact,
    mean ± 95% CI over seeds.

    Runs baseline and pr2ar2 under both configurations so the row also
    records how much of the GC-induced read tail the paper's combined
    mechanism claws back.  The (mechanism x seed x FTL-on/off) runs are
    independent cells scheduled through the sweep runtime (``workers``).
    """
    w = dataclasses.replace(w, n_requests=n_requests)
    cfg_gc = SSDConfig(gc=GCConfig(enabled=True))
    row = {
        "workload": w.name,
        "condition": cond.label(),
        "n_requests": n_requests,
        "span_pages": w.span_pages,
        "n_seeds": len(seeds),
    }
    mechs = ("baseline", "pr2ar2")
    cells = [
        Cell("simulate", w, (cond,), (mech,), s, cfg)
        for mech in mechs
        for s in seeds
        for cfg in (DEFAULT_SSD, cfg_gc)
    ]
    t0 = time.perf_counter()
    results = iter(run_cells(cells, workers=workers))
    row["wall_s"] = None    # filled after the drain below
    wa_list, gc_inv = [], []
    for mech in mechs:
        p99_off, p99_on, infl, mean_on = [], [], [], []
        for s in seeds:
            off = next(results)
            on = next(results)
            p99_off.append(off.read_p99_us)
            p99_on.append(on.read_p99_us)
            infl.append(on.read_p99_us / off.read_p99_us)
            mean_on.append(on.mean_us)
            if mech == "baseline":
                wa_list.append(on.wa)
                gc_inv.append(on.gc_invocations)
        mi, hi_ = mean_ci95(infl)
        row[mech] = {
            "read_p99_off_us": round(float(np.mean(p99_off)), 1),
            "read_p99_on_us": round(float(np.mean(p99_on)), 1),
            "read_p99_inflation_mean": round(mi, 2),
            "read_p99_inflation_ci95": round(hi_, 2),
            "mean_on_us": round(float(np.mean(mean_on)), 1),
        }
    row["wall_s"] = round(time.perf_counter() - t0, 3)
    wm, wh = mean_ci95(wa_list)
    row.update(
        wa_mean=round(wm, 3), wa_ci95=round(wh, 3),
        gc_invocations_mean=round(float(np.mean(gc_inv)), 1),
    )
    # The acceptance properties of the FTL subsystem (per-seed, all seeds):
    row["ok_wa_gt_1"] = bool(min(wa_list) > 1.0)
    row["ok_read_p99_higher"] = bool(
        min(row[m]["read_p99_inflation_mean"] for m in ("baseline", "pr2ar2"))
        > 1.0
    )
    return row


# -- scheduler cells: online GC x die-queue policy ------------------------


def bench_sched_cell(w, cond, n_requests, seeds, mech="baseline",
                     workers=1):
    """Online GC under fcfs / host_prio / preempt for one GC profile.

    Inflation is host-read p99 with GC on over GC off (same seed, same
    scheduler-independent off-run).  The acceptance: host_prio and
    preempt cut fcfs inflation >= 2x at equal (±10%) WA.  The off-runs
    and every (policy x seed) on-run are independent cells scheduled
    through the sweep runtime (``workers``).
    """
    w = dataclasses.replace(w, n_requests=n_requests)
    row = {
        "workload": w.name,
        "condition": cond.label(),
        "mechanism": mech,
        "n_requests": n_requests,
        "n_seeds": len(seeds),
        "gc_mode": "online",
    }
    cells = [Cell("simulate", w, (cond,), (mech,), s) for s in seeds]
    cells += [
        Cell("simulate", w, (cond,), (mech,), s, scheduler=sched,
             gc="online")
        for sched in SCHED_POLICIES
        for s in seeds
    ]
    t0 = time.perf_counter()
    results = run_cells(cells, workers=workers)
    wall = time.perf_counter() - t0
    off_p99 = {s: st.read_p99_us for s, st in zip(seeds, results)}
    on_runs = iter(results[len(seeds):])
    row["wall_s"] = round(wall, 3)
    wa_by_policy = {}
    for sched in SCHED_POLICIES:
        infl, wa, stalls, susp = [], [], [], []
        for s in seeds:
            on = next(on_runs)
            infl.append(on.read_p99_us / off_p99[s])
            wa.append(on.wa)
            stalls.append(on.write_stalls)
            susp.append(on.gc_suspensions)
        mi, hi_ = mean_ci95(infl)
        wam, wah = mean_ci95(wa)
        wa_by_policy[sched] = wam
        row[sched] = {
            "read_p99_inflation_mean": round(mi, 2),
            "read_p99_inflation_ci95": round(hi_, 2),
            "wa_mean": round(wam, 3),
            "wa_ci95": round(wah, 3),
            "write_stalls_mean": round(float(np.mean(stalls)), 1),
            "gc_suspensions_mean": round(float(np.mean(susp)), 1),
        }
    f = row["fcfs"]["read_p99_inflation_mean"]
    row["inflation_cut_host_prio"] = round(
        f / row["host_prio"]["read_p99_inflation_mean"], 2)
    row["inflation_cut_preempt"] = round(
        f / row["preempt"]["read_p99_inflation_mean"], 2)
    row["ok_wa_equal"] = bool(
        max(wa_by_policy.values()) <= min(wa_by_policy.values()) * 1.10
    )
    row["ok_p99_cut_2x"] = bool(
        row["inflation_cut_host_prio"] >= 2.0
        and row["inflation_cut_preempt"] >= 2.0
    )
    return row


# -- workload cells: real-trace replay through ingestion + FTL ------------

#: Checked-in MSR-format excerpts (tests/data/) replayed per PR.  The
#: registry resolves them via the search path (cwd/tests/data when run
#: from the repo root); dense footprint remap is the file-scheme default,
#: which is what FTL auto-OP sizing needs for sparse real address spaces.
TRACE_SPECS = ("msr:web_0", "msr:src1_1")
TRACE_MECHS = ("baseline", "pr2", "ar2", "pr2ar2")

#: Per-seed Bernoulli keep probability: the seed axis for deterministic
#: file traces (each seed replays an independent 85% subsample).
TRACE_SAMPLE = 0.85


def bench_trace_cell(spec, cond, seeds, workers=1):
    """Replay one checked-in excerpt end-to-end: compare_mechanisms with
    prepass GC (FTL auto-sized from the remapped dense footprint),
    baseline vs PR²/AR², mean ± 95% CI over subsample seeds.  One
    compare cell per seed, scheduled through the sweep runtime."""
    src = get_source(spec)
    src_stats = trace_stats(src.trace(0))
    # Composable form (not string concatenation) so parameterized specs
    # in TRACE_SPECS keep working; the chain is identical to ?sample=.
    sub = src.with_transforms(Subsample(TRACE_SAMPLE))
    row = {
        "workload": spec,
        "condition": cond.label(),
        "mechanisms": list(TRACE_MECHS),
        "gc_mode": "prepass",
        "n_seeds": len(seeds),
        "sample": TRACE_SAMPLE,
        "source": {
            "n_requests": src_stats.n_requests,
            # iops is inf for a degenerate zero-time-span excerpt
            "iops": round(src_stats.iops) if math.isfinite(src_stats.iops)
            else None,
            "read_ratio": round(src_stats.read_ratio, 3),
            "mean_pages": round(src_stats.mean_pages, 2),
            "footprint_pages": src_stats.footprint_pages,
            "burstiness": round(src_stats.mmpp_burstiness, 2),
        },
    }
    per_mech = {m: {"mean_us": [], "read_p99_us": []} for m in TRACE_MECHS}
    wa_list, finite = [], True
    cells = [
        Cell("compare", sub, (cond,), TRACE_MECHS, s, gc="prepass")
        for s in seeds
    ]
    t0 = time.perf_counter()
    grids = run_cells(cells, workers=workers)
    wall = time.perf_counter() - t0
    for grid in grids:
        for m, st in grid.items():
            for f in ("mean_us", "p50_us", "p99_us", "read_p99_us", "wa"):
                if not np.isfinite(float(getattr(st, f))):
                    finite = False
            per_mech[m]["mean_us"].append(st.mean_us)
            per_mech[m]["read_p99_us"].append(st.read_p99_us)
        wa_list.append(grid["baseline"].wa)
    row["wall_s"] = round(wall, 3)
    for m in TRACE_MECHS:
        mm, mh = mean_ci95(per_mech[m]["mean_us"])
        pm, _ = mean_ci95(per_mech[m]["read_p99_us"])
        row[m] = {
            "mean_us": round(mm, 1), "mean_us_ci95": round(mh, 1),
            "read_p99_us": round(pm, 1),
        }
    reds = [
        1.0 - a / b
        for a, b in zip(per_mech["pr2ar2"]["mean_us"],
                        per_mech["baseline"]["mean_us"])
    ]
    rm, rh = mean_ci95(reds)
    wam, wah = mean_ci95(wa_list)
    row.update(
        pr2ar2_reduction_mean=round(rm, 4),
        pr2ar2_reduction_ci95=round(rh, 4),
        wa_mean=round(wam, 3), wa_ci95=round(wah, 3),
    )
    row["ok_finite"] = bool(finite)
    row["ok_wa_gt_1"] = bool(min(wa_list) > 1.0)
    return row


# -- fault cells: AR² misprediction rate vs latency win -------------------

#: Multipliers on the derived AR² misprediction probability.  0.0 is the
#: no-misprediction upper bound on the AR² win; the derived rate (1.0)
#: is the paper-realistic point; 4.0 stresses the tradeoff.
FAULT_MISPREDICT_SCALES = (0.0, 1.0, 4.0)


def bench_fault_cell(w, cond, n_requests, seeds, workers=1):
    """AR² misprediction-rate vs latency-win tradeoff, mean ± 95% CI.

    For each ``mispredict_scale`` the paper's combined mechanism
    (pr2ar2) runs against baseline under the seeded fault model: every
    misprediction costs one extra nominal-tR re-read on the die, so
    rising scales erode the reduced-tR latency win.  Uncorrectable
    reads stay on the *derived* ECC probability — the acceptance being
    that nothing is lost at the paper-default margin
    (``unrecoverable == 0``).  ``recovery_p99_us`` is the p99 response
    over recovery-affected requests.  One compare cell per
    (scale, seed), scheduled through the sweep runtime (``workers``).
    """
    w = dataclasses.replace(w, n_requests=n_requests)
    mechs = ("baseline", "pr2ar2")
    row = {
        "workload": w.name,
        "condition": cond.label(),
        "mechanisms": list(mechs),
        "n_requests": n_requests,
        "n_seeds": len(seeds),
        "mispredict_scales": list(FAULT_MISPREDICT_SCALES),
    }
    cells = [
        Cell("compare", w, (cond,), mechs, s,
             faults=FaultConfig(mispredict_scale=scale))
        for scale in FAULT_MISPREDICT_SCALES
        for s in seeds
    ]
    t0 = time.perf_counter()
    results = iter(run_cells(cells, workers=workers))
    unrecoverable_total = 0
    win_by_scale = {}
    for scale in FAULT_MISPREDICT_SCALES:
        rate, win, rec_p99, mis = [], [], [], []
        for s in seeds:
            grid = next(results)
            st, base = grid["pr2ar2"], grid["baseline"]
            rate.append(st.mispredicted_reads / st.n_requests)
            win.append(1.0 - st.mean_us / base.mean_us)
            rec_p99.append(st.recovery_p99_us)
            mis.append(st.mispredicted_reads)
            unrecoverable_total += st.unrecoverable + base.unrecoverable
        rm, rh = mean_ci95(rate)
        wm, wh = mean_ci95(win)
        win_by_scale[scale] = wm
        row[f"scale_{scale:g}"] = {
            "mispredict_rate_mean": round(rm, 5),
            "mispredict_rate_ci95": round(rh, 5),
            "mispredicted_reads_mean": round(float(np.mean(mis)), 1),
            "latency_win_mean": round(wm, 4),
            "latency_win_ci95": round(wh, 4),
            "recovery_p99_us_mean": round(float(np.mean(rec_p99)), 1),
        }
    row["wall_s"] = round(time.perf_counter() - t0, 3)
    row["unrecoverable_total"] = unrecoverable_total
    row["ok_unrecoverable_zero"] = bool(unrecoverable_total == 0)
    row["ok_mispredicted_fired"] = bool(
        row["scale_1"]["mispredicted_reads_mean"] > 0
    )
    row["ok_win_erodes"] = bool(
        win_by_scale[FAULT_MISPREDICT_SCALES[0]]
        >= win_by_scale[FAULT_MISPREDICT_SCALES[-1]]
    )
    return row


# -- closed-loop cells: throughput-vs-QD ladder ---------------------------

#: NCQ depths of the saturation ladder (powers of two through the knee).
CLOSED_QD_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256)
CLOSED_QD_LADDER_QUICK = (1, 4, 16, 64, 256)
#: Fixed depth for the PR² overlap-win and host-cache rungs: past the
#: linear region, before open-loop convergence.
CLOSED_WIN_QD = 8


def bench_closed_loop_cell(w, cond, n_requests, seeds, quick=False,
                           workers=1):
    """Closed-loop frontend: throughput-vs-QD ladder, mean ± 95% CI.

    Every rung replays one GC write-cliff profile through the NCQ-gated
    frontend (``gc="prepass"``) for baseline and pr2ar2; an open-loop
    compare cell per seed anchors the QD-bounded-p99 check and a
    write-back-cache rung at ``CLOSED_WIN_QD`` records the absorption
    counters.  Acceptance flags:

    * ``ok_throughput_monotone`` — mean pr2ar2 throughput never drops as
      the queue deepens (and the ladder shows a knee: the top rung no
      longer scales linearly);
    * ``ok_qd_bounded_p99`` — the device-side read p99 at every bounded
      rung (QD <= 16) stays at or below the open-loop read p99 (admission
      control bounds device queueing on the GC write cliff);
    * ``ok_pr2_overlap_win`` — at ``CLOSED_WIN_QD`` the pipelined
      mechanism (CACHE READ: next sense under the current DMA transfer)
      beats serial baseline on closed-loop throughput.
    """
    ladder = CLOSED_QD_LADDER_QUICK if quick else CLOSED_QD_LADDER
    win_qd = (CLOSED_WIN_QD if CLOSED_WIN_QD in ladder
              else ladder[len(ladder) // 2])
    w = dataclasses.replace(w, n_requests=n_requests)
    mechs = ("baseline", "pr2ar2")
    hc = HostCacheConfig(capacity_pages=max(64, n_requests // 8))
    cells = [
        Cell("compare", w, (cond,), mechs, s, gc="prepass", ncq_depth=qd)
        for qd in ladder
        for s in seeds
    ]
    cells += [Cell("compare", w, (cond,), mechs, s, gc="prepass")
              for s in seeds]                       # open-loop anchor
    cells += [Cell("compare", w, (cond,), mechs, s, gc="prepass",
                   ncq_depth=win_qd, host_cache=hc)
              for s in seeds]                       # write-back cache rung
    t0 = time.perf_counter()
    results = iter(run_cells(cells, workers=workers))
    row = {
        "workload": w.name,
        "condition": cond.label(),
        "n_requests": n_requests,
        "n_seeds": len(seeds),
        "qd_ladder": list(ladder),
        "win_qd": win_qd,
    }
    iops_by_qd = {}
    rungs = []
    for qd in ladder:
        iops_b, iops_p, dev_p99, wait = [], [], [], []
        for s in seeds:
            grid = next(results)
            st, base = grid["pr2ar2"], grid["baseline"]
            iops_b.append(base.throughput_iops)
            iops_p.append(st.throughput_iops)
            dev_p99.append(st.read_device_p99_us)
            wait.append(st.hostq_wait_mean_us)
        im, ih = mean_ci95(iops_p)
        bm, bh = mean_ci95(iops_b)
        dm, dh = mean_ci95(dev_p99)
        iops_by_qd[qd] = im
        rungs.append({
            "qd": qd,
            "throughput_iops_mean": round(im, 1),
            "throughput_iops_ci95": round(ih, 1),
            "baseline_iops_mean": round(bm, 1),
            "baseline_iops_ci95": round(bh, 1),
            "read_device_p99_us_mean": round(dm, 1),
            "read_device_p99_us_ci95": round(dh, 1),
            "hostq_wait_mean_us": round(float(np.mean(wait)), 1),
        })
    row["rungs"] = rungs
    open_p99 = []
    for s in seeds:
        grid = next(results)
        open_p99.append(grid["pr2ar2"].read_p99_us)
    om, oh = mean_ci95(open_p99)
    row["open_loop_read_p99_us_mean"] = round(om, 1)
    row["open_loop_read_p99_us_ci95"] = round(oh, 1)
    hit_p, absw, stalls, mean_c = [], [], [], []
    for s in seeds:
        grid = next(results)
        st = grid["pr2ar2"]
        hit_p.append(st.cache_hit_pages)
        absw.append(st.cache_absorbed_writes)
        stalls.append(st.cache_stalled_writes)
        mean_c.append(st.mean_us)
    row["cache_rung"] = {
        "qd": win_qd,
        "capacity_pages": hc.capacity_pages,
        "absorbed_writes_mean": round(float(np.mean(absw)), 1),
        "hit_pages_mean": round(float(np.mean(hit_p)), 1),
        "stalled_writes_mean": round(float(np.mean(stalls)), 1),
        "mean_us": round(float(np.mean(mean_c)), 1),
    }
    row["wall_s"] = round(time.perf_counter() - t0, 3)

    ladder_iops = [iops_by_qd[qd] for qd in ladder]
    # 2% slack: past saturation, deeper queues reshuffle GC interleaving
    # and the plateau can dip fractionally.
    monotone = all(b >= a * 0.98
                   for a, b in zip(ladder_iops, ladder_iops[1:]))
    has_knee = ladder_iops[-1] < ladder_iops[-2] * 1.5
    row["ok_throughput_monotone"] = bool(monotone and has_knee)
    bounded = [r for r in rungs if r["qd"] <= 16]
    row["ok_qd_bounded_p99"] = bool(all(
        r["read_device_p99_us_mean"] <= om * (1 + 1e-9) for r in bounded
    ))
    win = next(r for r in rungs if r["qd"] == win_qd)
    row["pr2_overlap_speedup"] = round(
        win["throughput_iops_mean"] / win["baseline_iops_mean"], 3)
    row["ok_pr2_overlap_win"] = bool(row["pr2_overlap_speedup"] > 1.0)
    return row


# -- parallel-sweep cells: the runtime's workers speedup ------------------


def bench_parallel_sweep(n_requests, seeds, quick, workers):
    """Measure the sweep executor: the paper-claim grid at workers=1 vs
    workers=N on the same host, same run.

    The acceptance contract has two halves: per-cell results must be
    *identical* (``cells_equal`` — SimStats dataclass equality over the
    whole grid), and the wall-clock ``speedup`` is recorded alongside
    the host fingerprint (a 2-core/CPU-quota'd host cannot show the
    >= 2x a 4-core host does; the fingerprint makes that legible).

    On a single-core host the speedup half of the contract is
    unmeasurable — extra workers can only add process overhead, and a
    recorded sub-1x "speedup" reads as a runtime regression when it is
    purely a host property.  The block is therefore *gated* on the
    fingerprint: with ``cpu_count < 2`` it carries ``skipped`` +
    ``skipped_reason`` instead of misleading numbers (result equality
    across worker counts stays covered by ``bench_compare``'s
    deterministic-payload diff, which runs regardless).
    """
    cpus = int(host_fingerprint().get("cpu_count") or 1)
    if cpus < 2:
        return {
            "workers": workers,
            "skipped": True,
            "skipped_reason": (
                f"cpu_count={cpus} < 2: parallel-sweep speedup is not "
                "measurable on a single-core host; worker-count result "
                "equality is asserted by bench_compare instead"),
        }
    profiles = PROFILES[:2] if quick else PROFILES
    mechs = ("baseline", "pr2ar2")
    grids, walls = {}, {}
    for wk in (1, workers):
        t0 = time.perf_counter()
        grids[wk] = {
            w.name: simulate_batch(
                w, (AGED,), mechanisms=mechs, seeds=seeds,
                n_requests=n_requests, workers=wk,
            )
            for w in profiles
        }
        walls[wk] = time.perf_counter() - t0
    return {
        "workers": workers,
        "sweep_cells": len(profiles) * len(mechs) * len(seeds),
        "n_requests": n_requests,
        "wall_workers1_s": round(walls[1], 3),
        "wall_workersN_s": round(walls[workers], 3),
        "speedup": round(walls[1] / walls[workers], 2),
        "cells_equal": bool(grids[1] == grids[workers]),
    }


# -- shard-scaling cells: lockstep batched core vs the interpreter --------


def _engine_pair_row(cfg, w, seeds, mech):
    """Array-vs-batched measurement for one config: best-of-3 walls per
    (seed, engine), per-seed bit parity (full SimStats equality),
    fast-path-activated flag, and the events/sec speedup mean ± CI."""
    walls = {"array": [], "batched": []}
    eps = {"array": [], "batched": []}
    ratios, parity = [], True
    fast_path = True
    # warm every (cfg, engine, seed) triple: each seed's trace can land
    # in a different static-shape bucket (capsteps/capq), so one warm
    # run per config still leaves jit compiles inside the timed loop
    for s in seeds:
        for eng in ("array", "batched"):
            SSDSim(cfg, AGED, RetryPolicy(mech), seed=s + 7,
                   engine=eng).run(cached_trace(w, seed=s))
    for s in seeds:
        trace = cached_trace(w, seed=s)
        stats = {}
        for eng in ("array", "batched"):
            # best-of-3: scheduler jitter on a shared host is ±30%
            # one-sided slowdown; min is the standard estimator of
            # the undisturbed wall
            best = None
            for _ in range(3):
                sim = SSDSim(cfg, AGED, RetryPolicy(mech), seed=s + 7,
                             engine=eng)
                t0 = time.perf_counter()
                stats[eng] = sim.run(trace)
                dt = time.perf_counter() - t0
                best = dt if best is None else min(best, dt)
            walls[eng].append(best)
            eps[eng].append(sim.events_processed / best)
        parity = parity and stats["array"] == stats["batched"]
        fast_path = fast_path and \
            stats["batched"].fast_path_events > 0
        ratios.append(eps["batched"][-1] / eps["array"][-1])
    row = {"bit_parity": bool(parity),
           "fast_path_active": bool(fast_path)}
    for eng in ("array", "batched"):
        wm, wh = mean_ci95(walls[eng])
        em, eh = mean_ci95(eps[eng])
        row[eng] = {
            "wall_mean_s": round(wm, 4), "wall_ci95_s": round(wh, 4),
            "events_per_sec_mean": round(em),
            "events_per_sec_ci95": round(eh),
        }
    rm, rh = mean_ci95(ratios)
    row["batched_speedup_mean"] = round(rm, 3)
    row["batched_speedup_ci95"] = round(rh, 3)
    return row


def bench_small_cell_sweep(seeds, n_requests=500):
    """Sweep-level dispatch overhead: tiny cells, where fixed per-run
    cost (trace prep, kernel dispatch, shape-bucket padding, jit cache
    lookup) dominates the event loop.

    The same grid — 2 workloads x {baseline, pr2ar2} x {fcfs,
    host_prio} x seeds at n=500 — is pushed through ``run_cells`` twice:
    ``engine="array"`` and ``engine="auto"`` (auto must resolve to
    batched on every cell of this grid, and each returned SimStats
    records that in ``engine_selected``).  With the persistent compile
    cache and shape-bucketed padding the batched sweep must not lose to
    the interpreter even at this size — the evidence that the batched
    core's fixed overhead is gone at sweep level, not just amortized at
    n=8000.  Best-of-3 sweep walls; results must be equal cell-for-cell.
    """
    grid_w = [p for p in PROFILES if p.name in ("websearch", "oltp")]
    mechs = ("baseline", "pr2ar2")
    scheds = (None, "host_prio")

    def grid(engine):
        return [Cell("simulate", w, (AGED,), (m,), s,
                     n_requests=n_requests, engine=engine, scheduler=sc)
                for w in grid_w for m in mechs for sc in scheds
                for s in seeds]

    results, walls = {}, {}
    for eng in ("array", "auto"):
        cells = grid(eng)
        run_cells(cells)  # warm: char tables + every jit shape bucket
        best = None
        for _ in range(3):
            t0 = time.perf_counter()
            results[eng] = run_cells(cells)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        walls[eng] = best
    equal = results["array"] == results["auto"]
    auto_batched = all(r.engine_selected == "batched"
                       for r in results["auto"])
    speedup = walls["array"] / walls["auto"]
    return {
        "n_requests": n_requests,
        "cells": len(results["array"]),
        "seeds": len(seeds),
        "workloads": [w.name for w in grid_w],
        "mechanisms": list(mechs),
        "schedulers": ["fcfs" if s is None else s for s in scheds],
        "wall_array_s": round(walls["array"], 3),
        "wall_batched_s": round(walls["auto"], 3),
        "sweep_speedup": round(speedup, 3),
        "cells_equal": bool(equal),
        "auto_selected_batched_all": bool(auto_batched),
        "acceptance_small_cell_ok": bool(
            speedup >= 1.0 and equal and auto_batched),
    }


# -- fused sweep cells: cross-cell vectorized dispatch (ISSUE 10) ---------


def _fused_grid_row(grid_w, mechs, scheds, seeds, n_requests, rounds):
    """One fused-sweep measurement grid: fused vs sequential-batched vs
    array through ``run_cells``, interleaved timing rounds (drift
    cancels), per-cell bit-parity flags, and the fused dispatch count.
    """
    from repro.kernels.fcfs_core import ops as kops

    def mk(engine, fuse):
        return [Cell("simulate", w, (AGED,), (m,), s,
                     n_requests=n_requests, engine=engine, scheduler=sc,
                     fuse=fuse)
                for w in grid_w for m in mechs for sc in scheds
                for s in seeds]

    variants = {"fused": ("batched", True),
                "sequential": ("batched", False),
                "array": ("array", None)}
    results = {}
    for name, (eng, fz) in variants.items():   # warm: char + jit buckets
        results[name] = run_cells(mk(eng, fz))
    before = kops.KERNEL_DISPATCHES
    run_cells(mk("batched", True))
    fused_dispatches = kops.KERNEL_DISPATCHES - before
    before = kops.KERNEL_DISPATCHES
    run_cells(mk("batched", False))
    sequential_dispatches = kops.KERNEL_DISPATCHES - before

    # Interleaved rounds with the collector parked: adjacent
    # measurements see the same host state, and GC pauses (pure jitter
    # at these sub-second walls) hit no variant.
    walls = {name: [] for name in variants}
    gc.collect()
    gc.disable()
    try:
        for _ in range(rounds):
            for name, (eng, fz) in variants.items():
                cells = mk(eng, fz)
                t0 = time.perf_counter()
                results[name] = run_cells(cells)
                walls[name].append(time.perf_counter() - t0)
    finally:
        gc.enable()

    parity_vs_sequential = [bool(a == b) for a, b in
                            zip(results["fused"], results["sequential"])]
    parity_vs_array = [bool(a == b) for a, b in
                       zip(results["fused"], results["array"])]
    n_cells = len(results["fused"])
    row = {
        "n_requests": n_requests,
        "cells": n_cells,
        "seeds": len(seeds),
        "rounds": rounds,
        "workloads": [w.name for w in grid_w],
        "mechanisms": list(mechs),
        "schedulers": ["fcfs" if s is None else s for s in scheds],
        "fused_dispatches": fused_dispatches,
        "sequential_dispatches": sequential_dispatches,
        "fused_cells_per_dispatch": sorted(
            {r.fused_cells for r in results["fused"]}),
        "parity_vs_sequential": parity_vs_sequential,
        "parity_vs_array": parity_vs_array,
        "parity_all": bool(all(parity_vs_sequential)
                           and all(parity_vs_array)),
    }
    # Machine-free normalization: cell throughput (requests/s) relative
    # to the same run's array sweep.
    thr = {}
    for name in variants:
        wm, wh = mean_ci95(walls[name])
        best = min(walls[name])
        thr[name] = n_cells * n_requests / best
        row[name] = {
            "wall_mean_s": round(wm, 4),
            "wall_ci95_s": round(wh, 4),
            "wall_best_s": round(best, 4),
        }
    for name in variants:
        row[name]["rel_throughput"] = round(thr[name] / thr["array"], 3)
    row["speedup_vs_sequential"] = round(
        row["sequential"]["wall_best_s"] / row["fused"]["wall_best_s"], 3)
    row["speedup_vs_array"] = round(
        row["array"]["wall_best_s"] / row["fused"]["wall_best_s"], 3)
    return row


def bench_fused_sweep_cells(seeds, n_claim, quick=False):
    """Fused sweep core vs the sequential batched engine vs the array
    interpreter (ISSUE 10).

    Two grids, both pushed through ``run_cells`` three ways —
    ``engine="batched"`` with fusion on (cross-cell stacked dispatches),
    fusion off (one dispatch per cell), and ``engine="array"``:

      * the **small-cell grid** — the n=500 dispatch-overhead grid of
        :func:`bench_small_cell_sweep` (2 workloads x {baseline, pr2ar2}
        x {fcfs, host_prio} x seeds), where fixed per-dispatch cost
        dominates and fusion pays most; the acceptance rides here:
        ``speedup_vs_sequential >= 1.5`` with every parity flag true;
      * the **claim grid** — the paper-claim mechanism pair over the
        claim profiles at the acceptance size (n=8000), where the
        lockstep event loop dominates and fusion's win shrinks to the
        amortized dispatch overhead (recorded, not gated).

    Walls are interleaved rounds (mean ± 95% CI + best); per-cell
    bit-parity flags compare full SimStats equality fused-vs-sequential
    and fused-vs-array; ``fused_dispatches`` vs
    ``sequential_dispatches`` records the kernel-launch accounting
    (``KERNEL_DISPATCHES``).  ``rel_throughput`` normalizes each
    variant's request throughput to the same run's array sweep, so
    cross-machine comparisons stay machine-free.
    """
    grid_w = [p for p in PROFILES if p.name in ("websearch", "oltp")]
    mechs = ("baseline", "pr2ar2")
    # Claim grid first: its long runs leave the process (allocator
    # pools, jit caches, branch predictors) fully hot before the gated
    # small-grid measurement — the first grid measured in a fresh
    # process reads consistently slow for every variant.
    claim_w = PROFILES[:2] if quick else PROFILES
    claim = _fused_grid_row(claim_w, mechs, (None,), seeds, n_claim,
                            2 if quick else 3)
    small = _fused_grid_row(grid_w, mechs, (None, "host_prio"), seeds,
                            500, 3 if quick else 8)
    return {
        "small_cell_grid": small,
        "claim_grid": claim,
        "speedup_small_grid": small["speedup_vs_sequential"],
        "speedup_claim_grid": claim["speedup_vs_sequential"],
        "parity_all": bool(small["parity_all"] and claim["parity_all"]),
        "acceptance_fused_sweep_ok": bool(
            small["speedup_vs_sequential"] >= 1.5
            and small["parity_all"] and claim["parity_all"]),
    }


def bench_shard_scaling(n_requests, seeds):
    """Single-cell engine scaling: wall vs channel count, the array
    interpreter vs the lockstep batched core
    (:mod:`repro.flashsim.engine_batched`), websearch @ aged.

    Per (n_channels, engine) cell: mean ± 95% CI of wall seconds and
    events/sec over the seeds, plus per-seed bit-parity (full SimStats
    dataclass equality between the engines) and whether the Pallas fast
    path actually ran (``fast_path_events`` counter).  ``rel_throughput``
    normalizes every cell against this run's 8-channel array cell, so
    the scaling shape is machine-free; absolute walls are host-dependent
    (the top-level fingerprint records the core count — a CPU-quota'd
     1-core container cannot show multi-core scaling, but the batched
    speedup is in-process and holds regardless).

    Two companion blocks ride along:

      * ``scheduler_cells_8ch`` — the 8-channel cell re-measured under
        the dual priority rings (host_prio, host_prio_aged): the
        priority lowering must keep bit parity *and* keep paying at
        8 channels (acceptance: batched >= 1.3x array under host_prio);
      * ``small_cell_sweep`` — :func:`bench_small_cell_sweep`, the
        n=500 dispatch-overhead gate.

    The headline acceptance gate rides on the 8-channel fcfs cell:
    ``batched_speedup_mean >= 1.5`` (events/sec, batched / array).
    """
    w0 = next(p for p in PROFILES if p.name == "websearch")
    w = dataclasses.replace(w0, n_requests=n_requests)
    mech = "baseline"
    channel_rows = []
    for c in (1, 2, 4, 8):
        cfg = dataclasses.replace(DEFAULT_SSD, n_channels=c)
        channel_rows.append(
            {"n_channels": c, **_engine_pair_row(cfg, w, seeds, mech)})
    sched_rows = []
    for sched in ("host_prio", "host_prio_aged"):
        cfg = dataclasses.replace(DEFAULT_SSD, n_channels=8,
                                  scheduler=sched)
        sched_rows.append(
            {"scheduler": sched, "n_channels": 8,
             **_engine_pair_row(cfg, w, seeds, mech)})
    ref_eps = next(r for r in channel_rows if r["n_channels"] == 8
                   )["array"]["events_per_sec_mean"]
    for r in channel_rows + sched_rows:
        for eng in ("array", "batched"):
            r[eng]["rel_throughput"] = round(
                r[eng]["events_per_sec_mean"] / ref_eps, 3)
    ch8 = channel_rows[-1]
    hp8 = next(r for r in sched_rows if r["scheduler"] == "host_prio")
    all_rows = channel_rows + sched_rows
    return {
        "workload": w0.name,
        "condition": AGED.label(),
        "mechanism": mech,
        "n_requests": n_requests,
        "seeds": len(seeds),
        "channels": channel_rows,
        "scheduler_cells_8ch": sched_rows,
        "bit_parity_all": bool(all(r["bit_parity"] for r in all_rows)),
        "fast_path_all": bool(
            all(r["fast_path_active"] for r in all_rows)),
        "speedup_8ch_mean": ch8["batched_speedup_mean"],
        "speedup_8ch_ci95": ch8["batched_speedup_ci95"],
        "acceptance_8ch_speedup_ok": bool(
            ch8["batched_speedup_mean"] >= 1.5),
        "speedup_8ch_host_prio_mean": hp8["batched_speedup_mean"],
        "speedup_8ch_host_prio_ci95": hp8["batched_speedup_ci95"],
        "acceptance_8ch_host_prio_ok": bool(
            hp8["batched_speedup_mean"] >= 1.3),
        "small_cell_sweep": bench_small_cell_sweep(seeds),
        # multi-core *process* scaling is a different (host-gated)
        # claim; this cell's speedup is single-process lockstep
        "host_dependent": "wall times; see top-level host fingerprint",
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=None,
                    help="requests per cell (default 8000; 1200 in --quick)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--seeds", type=int, default=None,
                    help="seeds per claim/GC/scheduler cell "
                         "(default 5; 2 in --quick)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="process-pool workers for the sweep cells "
                         "(default 4; 1 in --quick)")
    ap.add_argument("--skip-reference", action="store_true")
    ap.add_argument("--skip-gc", action="store_true")
    ap.add_argument("--skip-traces", action="store_true")
    ap.add_argument("--out", default="BENCH_sim.json")
    args = ap.parse_args()
    n = args.n if args.n is not None else (1200 if args.quick else 8000)
    n_seeds = args.seeds if args.seeds is not None else (2 if args.quick else 5)
    if n_seeds < 1:
        ap.error("--seeds must be >= 1")
    workers = args.workers if args.workers is not None else \
        (1 if args.quick else 4)
    if workers < 1:
        ap.error("--workers must be >= 1")
    seeds = tuple(range(args.seed, args.seed + n_seeds))

    cells = e2e_cells(args.quick)
    warm_s = warm_characterization(cells)
    print(f"# characterization warm: {warm_s:.1f}s ({len(cells)} cells)")

    rows = []
    for w, cond, mechs in cells:
        row = bench_cell(w, cond, mechs, n, args.seed, args.skip_reference)
        rows.append(row)
        spd = f" speedup={row['speedup']:5.2f}x" if "speedup" in row else ""
        print(
            f"{w.name:10s} @ {cond.label():>10s} x{len(mechs)} mechs: "
            f"array {row['wall_array_s']:6.3f}s "
            f"({row['events_per_sec_array'] / 1e6:.2f}M ev/s){spd}"
        )

    t0 = time.perf_counter()
    claim_rows, claim_summary = bench_claim_cells(n, seeds, args.quick,
                                                  workers=workers)
    print(
        f"# claim CI ({len(seeds)} seeds, {time.perf_counter() - t0:.1f}s): "
        f"vs baseline -{100 * claim_summary['avg_vs_baseline']['mean']:.1f}%"
        f"±{100 * claim_summary['avg_vs_baseline']['ci95']:.1f} "
        f"(paper -35.7%) | vs SOTA "
        f"-{100 * claim_summary['avg_vs_sota']['mean']:.1f}%"
        f"±{100 * claim_summary['avg_vs_sota']['ci95']:.1f} (paper -21.8%) "
        f"-> {'OK' if claim_summary['claim_ci_overlap_ok'] else 'MISMATCH'}"
    )

    gc_rows, sched_rows = [], []
    gc_carried = False
    if args.skip_gc:
        # Don't clobber the recorded GC trajectory: carry the previous
        # file's GC cells forward (flagged so readers know they're stale).
        try:
            with open(args.out) as f:
                prev = json.load(f)
            gc_rows = prev.get("gc_cells", [])
            sched_rows = prev.get("sched_cells", [])
            gc_carried = bool(gc_rows or sched_rows)
        except (OSError, ValueError):
            pass
    else:
        n_gc = GC_QUICK_N if args.quick else n
        gc_profiles = GC_PROFILES[:1] if args.quick else GC_PROFILES
        for w in gc_profiles:
            row = bench_gc_cell(w, AGED, n_gc, seeds, workers=workers)
            gc_rows.append(row)
            print(
                f"GC {w.name:8s} @ {row['condition']:>10s}: "
                f"WA={row['wa_mean']:.2f}±{row['wa_ci95']:.2f} "
                f"read_p99 x{row['baseline']['read_p99_inflation_mean']:.1f}"
                f"±{row['baseline']['read_p99_inflation_ci95']:.1f} "
                f"(pr2ar2 x{row['pr2ar2']['read_p99_inflation_mean']:.1f}) "
                f"ok={row['ok_wa_gt_1'] and row['ok_read_p99_higher']}"
            )
        for w in gc_profiles:
            row = bench_sched_cell(w, AGED, n_gc, seeds, workers=workers)
            sched_rows.append(row)
            print(
                f"SCHED {w.name:8s} online-GC inflation: "
                f"fcfs x{row['fcfs']['read_p99_inflation_mean']:.1f} -> "
                f"host_prio x{row['host_prio']['read_p99_inflation_mean']:.1f} "
                f"(cut {row['inflation_cut_host_prio']:.0f}x) -> "
                f"preempt x{row['preempt']['read_p99_inflation_mean']:.1f} "
                f"(cut {row['inflation_cut_preempt']:.0f}x) "
                f"wa_eq={row['ok_wa_equal']} ok={row['ok_p99_cut_2x']}"
            )

    trace_rows = []
    trace_carried = False
    if args.skip_traces:
        try:
            with open(args.out) as f:
                prev = json.load(f)
            trace_rows = prev.get("trace_cells", [])
            trace_carried = bool(trace_rows)
        except (OSError, ValueError):
            pass
    else:
        specs = TRACE_SPECS[:1] if args.quick else TRACE_SPECS
        for spec in specs:
            row = bench_trace_cell(spec, AGED, seeds, workers=workers)
            trace_rows.append(row)
            print(
                f"TRACE {spec:12s} ({row['source']['n_requests']} reqs, "
                f"rd={row['source']['read_ratio']:.2f}): "
                f"baseline {row['baseline']['mean_us']:.0f}us -> pr2ar2 "
                f"{row['pr2ar2']['mean_us']:.0f}us "
                f"(-{100 * row['pr2ar2_reduction_mean']:.1f}%"
                f"±{100 * row['pr2ar2_reduction_ci95']:.1f}) "
                f"WA={row['wa_mean']:.2f} ok={row['ok_finite']}"
            )

    fault_rows = []
    fprofiles = [w for w in PROFILES if w.read_dominant]
    fprofiles = fprofiles[:1] if args.quick else fprofiles[:2]
    for w in fprofiles:
        row = bench_fault_cell(w, AGED, n, seeds, workers=workers)
        fault_rows.append(row)
        d = row["scale_1"]
        print(
            f"FAULT {w.name:10s} @ {row['condition']:>10s}: mispredict "
            f"{100 * d['mispredict_rate_mean']:.2f}%"
            f"±{100 * d['mispredict_rate_ci95']:.2f} -> win "
            f"{100 * d['latency_win_mean']:.1f}%"
            f"±{100 * d['latency_win_ci95']:.1f} "
            f"(clean {100 * row['scale_0']['latency_win_mean']:.1f}%, "
            f"x4 {100 * row['scale_4']['latency_win_mean']:.1f}%) "
            f"rec_p99 {d['recovery_p99_us_mean']:.0f}us "
            f"ok={row['ok_unrecoverable_zero'] and row['ok_win_erodes']}"
        )

    closed_rows = []
    for w in (GC_PROFILES[:1] if args.quick else GC_PROFILES[:2]):
        n_cl = GC_QUICK_N if args.quick else n
        row = bench_closed_loop_cell(w, AGED, n_cl, seeds,
                                     quick=args.quick, workers=workers)
        closed_rows.append(row)
        knee = row["rungs"][-1]
        ok = (row["ok_throughput_monotone"] and row["ok_qd_bounded_p99"]
              and row["ok_pr2_overlap_win"])
        print(
            f"CLOSED {w.name:8s} QD ladder "
            f"{row['rungs'][0]['throughput_iops_mean']:.0f} -> "
            f"{knee['throughput_iops_mean']:.0f} IOPS "
            f"(x{row['pr2_overlap_speedup']:.2f} vs baseline @QD"
            f"{row['win_qd']}) dev_p99<= "
            f"{row['open_loop_read_p99_us_mean']:.0f}us "
            f"ok={ok}"
        )

    parallel_row = None
    if workers > 1:
        t0 = time.perf_counter()
        parallel_row = bench_parallel_sweep(n, seeds, args.quick, workers)
        if parallel_row.get("skipped"):
            print(f"# parallel sweep skipped: "
                  f"{parallel_row['skipped_reason']}")
        else:
            print(
                f"# parallel sweep ({parallel_row['sweep_cells']} cells, "
                f"{time.perf_counter() - t0:.1f}s): workers=1 "
                f"{parallel_row['wall_workers1_s']:.2f}s -> "
                f"workers={workers} "
                f"{parallel_row['wall_workersN_s']:.2f}s "
                f"(speedup {parallel_row['speedup']:.2f}x, "
                f"equal={parallel_row['cells_equal']})"
            )

    t0 = time.perf_counter()
    shard_scaling = bench_shard_scaling(n, seeds)
    small = shard_scaling["small_cell_sweep"]
    print(
        f"# shard scaling ({time.perf_counter() - t0:.1f}s): "
        f"batched/array @8ch "
        f"{shard_scaling['speedup_8ch_mean']:.2f}x"
        f"±{shard_scaling['speedup_8ch_ci95']:.2f} "
        f"(host_prio {shard_scaling['speedup_8ch_host_prio_mean']:.2f}x"
        f"±{shard_scaling['speedup_8ch_host_prio_ci95']:.2f}) "
        f"parity={shard_scaling['bit_parity_all']} "
        f"fast_path={shard_scaling['fast_path_all']} "
        f"ok={shard_scaling['acceptance_8ch_speedup_ok']}"
        f"/{shard_scaling['acceptance_8ch_host_prio_ok']}"
    )
    print(
        f"# small-cell sweep (n={small['n_requests']}, "
        f"{small['cells']} cells): array {small['wall_array_s']:.2f}s -> "
        f"batched {small['wall_batched_s']:.2f}s "
        f"({small['sweep_speedup']:.2f}x, equal={small['cells_equal']}, "
        f"auto={small['auto_selected_batched_all']}, "
        f"ok={small['acceptance_small_cell_ok']})"
    )

    t0 = time.perf_counter()
    fused_sweep = bench_fused_sweep_cells(seeds, n, quick=args.quick)
    fs_small = fused_sweep["small_cell_grid"]
    fs_claim = fused_sweep["claim_grid"]
    print(
        f"# fused sweep ({time.perf_counter() - t0:.1f}s): small grid "
        f"(n={fs_small['n_requests']}, {fs_small['cells']} cells) "
        f"seq {fs_small['sequential']['wall_best_s']:.2f}s -> fused "
        f"{fs_small['fused']['wall_best_s']:.2f}s "
        f"({fs_small['speedup_vs_sequential']:.2f}x, "
        f"{fs_small['fused_dispatches']}/"
        f"{fs_small['sequential_dispatches']} dispatches) | claim grid "
        f"(n={fs_claim['n_requests']}) "
        f"{fs_claim['speedup_vs_sequential']:.2f}x "
        f"parity={fused_sweep['parity_all']} "
        f"ok={fused_sweep['acceptance_fused_sweep_ok']}"
    )

    total_array = sum(r["wall_array_s"] for r in rows)
    # Reference-cell normalization: cells_detail[0] is the pinned cell
    # (first e2e cell, websearch @ aged x all mechanisms); dividing each
    # cell's throughput by it cancels the machine.
    ref_eps = rows[0]["events_per_sec_array"]
    for r in rows:
        r["rel_throughput"] = round(r["events_per_sec_array"] / ref_eps, 3)
    reference_cell = {
        "workload": rows[0]["workload"],
        "condition": rows[0]["condition"],
        "n_requests": n,
        "events_per_sec_array": ref_eps,
        "pinned_events_per_sec": (
            REFERENCE_EVENTS_PER_SEC if n == REFERENCE_N else None
        ),
        # host_factor > 1: this host is faster than the machine class
        # that set the pin; None off the acceptance size (not comparable).
        "host_factor": (
            round(ref_eps / REFERENCE_EVENTS_PER_SEC, 3)
            if n == REFERENCE_N else None
        ),
    }
    summary = {
        "n_requests": n,
        "cells": len(rows),
        "wall_array_total_s": round(total_array, 3),
        "events_per_sec_array": round(
            sum(r["events_array"] for r in rows) / total_array
        ),
        "characterization_warm_s": round(warm_s, 2),
        "reference_cell": reference_cell,
        "claim": claim_summary,
    }
    summary["shard_scaling"] = {
        "speedup_8ch_mean": shard_scaling["speedup_8ch_mean"],
        "speedup_8ch_ci95": shard_scaling["speedup_8ch_ci95"],
        "bit_parity_all": shard_scaling["bit_parity_all"],
        "fast_path_all": shard_scaling["fast_path_all"],
        "acceptance_8ch_speedup_ok":
            shard_scaling["acceptance_8ch_speedup_ok"],
        "speedup_8ch_host_prio_mean":
            shard_scaling["speedup_8ch_host_prio_mean"],
        "speedup_8ch_host_prio_ci95":
            shard_scaling["speedup_8ch_host_prio_ci95"],
        "acceptance_8ch_host_prio_ok":
            shard_scaling["acceptance_8ch_host_prio_ok"],
        "small_cell_sweep_speedup": small["sweep_speedup"],
        "acceptance_small_cell_ok": small["acceptance_small_cell_ok"],
    }
    summary["fused_sweep"] = {
        "speedup_small_grid": fused_sweep["speedup_small_grid"],
        "speedup_claim_grid": fused_sweep["speedup_claim_grid"],
        "parity_all": fused_sweep["parity_all"],
        "acceptance_fused_sweep_ok":
            fused_sweep["acceptance_fused_sweep_ok"],
    }
    if parallel_row is not None:
        summary["parallel"] = parallel_row
    if not args.skip_reference:
        total_ref = sum(r["wall_seed_s"] for r in rows)
        summary["wall_seed_total_s"] = round(total_ref, 3)
        summary["speedup_total"] = round(total_ref / total_array, 2)
        summary["attempts_match_all"] = all(r["attempts_match"] for r in rows)
    if gc_rows:
        summary["gc_wa_max"] = max(r["wa_mean"] for r in gc_rows)
        summary["gc_acceptance_ok"] = all(
            r["ok_wa_gt_1"] and r["ok_read_p99_higher"] for r in gc_rows
        )
        if gc_carried:
            summary["gc_cells_carried"] = True  # from a previous run
    if sched_rows:
        summary["sched_acceptance_ok"] = all(
            r["ok_p99_cut_2x"] and r["ok_wa_equal"] for r in sched_rows
        )
        summary["sched_min_inflation_cut"] = min(
            min(r["inflation_cut_host_prio"], r["inflation_cut_preempt"])
            for r in sched_rows
        )
    if trace_rows:
        summary["trace_replay_ok"] = all(
            r["ok_finite"] and r["ok_wa_gt_1"] for r in trace_rows
        )
        summary["trace_cells_n"] = len(trace_rows)
        summary["trace_pr2ar2_reduction_mean"] = round(
            float(np.mean([r["pr2ar2_reduction_mean"] for r in trace_rows])),
            4,
        )
        if trace_carried:
            summary["trace_cells_carried"] = True  # from a previous run
    if closed_rows:
        summary["closed_loop_acceptance_ok"] = all(
            r["ok_throughput_monotone"] and r["ok_qd_bounded_p99"]
            and r["ok_pr2_overlap_win"]
            for r in closed_rows
        )
        summary["closed_loop_pr2_speedup_mean"] = round(
            float(np.mean([r["pr2_overlap_speedup"] for r in closed_rows])),
            3,
        )
    if fault_rows:
        summary["fault_acceptance_ok"] = all(
            r["ok_unrecoverable_zero"] and r["ok_mispredicted_fired"]
            and r["ok_win_erodes"]
            for r in fault_rows
        )
        summary["fault_unrecoverable_total"] = sum(
            r["unrecoverable_total"] for r in fault_rows
        )
        summary["fault_win_derived_mean"] = round(
            float(np.mean([r["scale_1"]["latency_win_mean"]
                           for r in fault_rows])), 4,
        )

    out = {"benchmark": "flashsim-des-engine",
           "host": host_fingerprint(),
           "summary": summary,
           "cells_detail": rows, "claim_cells": claim_rows,
           "gc_cells": gc_rows, "sched_cells": sched_rows,
           "trace_cells": trace_rows, "fault_cells": fault_rows,
           "closed_loop_cells": closed_rows,
           "shard_scaling_cells": shard_scaling,
           "fused_sweep_cells": fused_sweep}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"# summary: {json.dumps(summary)}")
    print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
