"""Paper Observation 2: a large ECC-capability margin exists in the final
retry step — even at the worst operating condition manufacturers prescribe
(1-year retention at 1.5K P/E cycles).

The margin is (t - E[errors/codeword]) / t at the success entry: positive
by construction whenever the retry succeeds (the paper's "may sound
contradictory" argument), and *large* because (a) the ECC is strong
(t = 72 per 1 KiB) and (b) the final entry reads at near-optimal V_REF.

Usage: PYTHONPATH=src python -m benchmarks.ecc_margin
"""

from __future__ import annotations

import time

from repro.core import characterize as CH

GRID = [
    (90.0, 0.0), (180.0, 500.0), (365.0, 1000.0), (365.0, 1500.0),
]

#: "Large" margin acceptance: the mean final-step margin must clear this at
#: every condition incl. worst-case (i.e. >1/3 of the capability unused).
LARGE_MARGIN_FLOOR = 0.33


def run(verbose: bool = True):
    rows = []
    for r, p in GRID:
        t0 = time.perf_counter()
        s = CH.characterize_condition(r, p)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((s, dt))
        if verbose:
            print(
                f"  {s.retention_days:6.0f}d {s.pec:6.0f}PE | "
                f"mean final-step margin {s.mean_margin_final:5.3f} | "
                f"p01 {s.p01_margin_final:6.3f}"
            )
    worst = next(
        s for s, _ in rows if s.retention_days == 365.0 and s.pec == 1500.0
    )
    ok = (
        worst.mean_margin_final >= LARGE_MARGIN_FLOOR
        and worst.p01_margin_final >= 0.0
    )
    if verbose:
        print(
            f"paper check: worst-case margin mean={worst.mean_margin_final:.3f} "
            f"(>= {LARGE_MARGIN_FLOOR}), p01={worst.p01_margin_final:.3f} (>= 0) "
            f"-> {'OK' if ok else 'MISMATCH'}"
        )
    assert ok
    return rows


def csv_rows():
    rows = run(verbose=False)
    return [
        (
            f"ecc_margin/{s.retention_days:.0f}d_{s.pec:.0f}pe",
            dt,
            f"mean={s.mean_margin_final:.3f};p01={s.p01_margin_final:.3f}",
        )
        for s, dt in rows
    ]


def main():
    print("Observation 2 — ECC-capability margin in the final retry step")
    run()


if __name__ == "__main__":
    main()
