"""Paper Observation 1: reads frequently need multiple retry steps.

Reproduces the characterization table over the (retention age x P/E cycle)
grid for the 160-chip population: mean/p99 retry steps and the fraction of
reads that retry at all.  Validates the abstract's quoted figure — on
average ~4.5 retry steps under a 3-month retention age at zero P/E cycles
— and the §2 claim that under the SOTA start predictor an *aged* SSD still
incurs >= 3 steps on every read.

Usage: PYTHONPATH=src python -m benchmarks.retry_characterization
"""

from __future__ import annotations

import time

from repro.core import characterize as CH

#: (retention_days, pec) cells printed, spanning modest -> worst-case.
GRID = [
    (0.0, 0.0), (7.0, 0.0), (30.0, 0.0), (90.0, 0.0),
    (90.0, 1000.0), (180.0, 1000.0), (365.0, 1000.0), (365.0, 1500.0),
]

PAPER_MEAN_STEPS_3MO = 4.5     # abstract: "on average 4.5 retry steps"
TOLERANCE = 0.5                # population/calibration tolerance


def run(verbose: bool = True):
    rows = []
    for r, p in GRID:
        t0 = time.perf_counter()
        s = CH.characterize_condition(r, p)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((s, dt))
        if verbose:
            print(
                f"  {s.retention_days:6.0f}d {s.pec:6.0f}PE | "
                f"mean retry steps {s.mean_retry_steps:6.2f} | "
                f"p99 {s.p99_retry_steps:5.1f} | "
                f"frac-with-retry {s.frac_reads_with_retry:5.2f}"
            )

    # Abstract validation: ~4.5 steps at 3 months / 0 P/E.
    s_3mo = next(s for s, _ in rows if s.retention_days == 90.0 and s.pec == 0.0)
    err = abs(s_3mo.mean_retry_steps - PAPER_MEAN_STEPS_3MO)
    ok = err <= TOLERANCE
    if verbose:
        print(
            f"paper check: mean steps @3mo/0PE = {s_3mo.mean_retry_steps:.2f} "
            f"(paper {PAPER_MEAN_STEPS_3MO}) -> {'OK' if ok else 'MISMATCH'}"
        )
    assert ok, f"calibration drifted: {s_3mo.mean_retry_steps:.2f} vs 4.5"
    return rows


def csv_rows():
    rows = run(verbose=False)
    out = []
    for s, dt in rows:
        out.append(
            (
                f"retry_char/{s.retention_days:.0f}d_{s.pec:.0f}pe",
                dt,
                f"mean_steps={s.mean_retry_steps:.2f};p99={s.p99_retry_steps:.1f};"
                f"frac={s.frac_reads_with_retry:.2f}",
            )
        )
    return out


def main():
    print("Observation 1 — retry-step characterization (160-chip population)")
    run()


if __name__ == "__main__":
    main()
