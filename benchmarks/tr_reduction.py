"""Paper Observation 3 + AR² table: tR is safely reducible by 25% even at
the worst prescribed operating condition.

For every condition the AR² search (core/characterize.py) re-runs the whole
retry search at each candidate tR scale and admits a scale only if the
expected attempt count stays within budget of the full-tR count — the
paper's "without increasing the number of retry steps".  The resulting
best-scale table IS the AR² lookup table shipped in the framework.

Validates: scale 0.75 admissible at (1 yr, 1.5K P/E); 0.60 not admissible
anywhere near worst-case (the calibration pins the safety boundary).

Usage: PYTHONPATH=src python -m benchmarks.tr_reduction
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import characterize as CH
from repro.core import constants as C
from repro.core import retry as R

GRID = [
    (30.0, 0.0), (90.0, 0.0), (180.0, 500.0),
    (365.0, 1000.0), (365.0, 1500.0),
]


def attempt_delta_at_scale(retention, pec, scale, seed=0):
    """Mean extra attempts caused by sensing at ``scale`` (vs full tR)."""
    import jax

    deltas = []
    for i, pt in enumerate(C.PAGE_TYPES):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        a_full, _ = R.attempts_for_population(key, retention, pec, pt, tr_scale=1.0)
        a_s, _ = R.attempts_for_population(key, retention, pec, pt, tr_scale=scale)
        deltas.append(float(np.mean(np.asarray(a_s) - np.asarray(a_full))))
    return float(np.mean(deltas))


def run(verbose: bool = True):
    rows = []
    for r, p in GRID:
        t0 = time.perf_counter()
        s = CH.characterize_condition(r, p)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((s, dt))
        if verbose:
            print(
                f"  {s.retention_days:6.0f}d {s.pec:6.0f}PE | "
                f"best safe tR scale {s.safe_tr_scale:4.2f} "
                f"(reduction {100 * (1 - s.safe_tr_scale):4.1f}%)"
            )

    worst = next(s for s, _ in rows if s.retention_days == 365.0 and s.pec == 1500.0)
    ok_75 = worst.safe_tr_scale <= 0.75          # >= 25% reduction admissible
    d60 = attempt_delta_at_scale(365.0, 1500.0, 0.60)
    ok_60 = d60 > CH.EXTRA_ATTEMPT_BUDGET        # 40% reduction is NOT safe
    if verbose:
        print(
            f"paper check: worst-case best scale {worst.safe_tr_scale:.2f} "
            f"(<= 0.75: {'OK' if ok_75 else 'MISMATCH'}); "
            f"0.60 would add {d60:.2f} attempts/read "
            f"(unsafe: {'OK' if ok_60 else 'MISMATCH'})"
        )
    assert ok_75 and ok_60
    return rows


def csv_rows():
    rows = run(verbose=False)
    return [
        (
            f"tr_reduction/{s.retention_days:.0f}d_{s.pec:.0f}pe",
            dt,
            f"safe_scale={s.safe_tr_scale:.2f}",
        )
        for s, dt in rows
    ]


def main():
    print("Observation 3 / AR² table — safe tR reduction per condition")
    run()


if __name__ == "__main__":
    main()
